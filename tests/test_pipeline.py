"""Unified scan pipeline: plan-at-open, streaming TQL execution parity,
cross-unit prefetch, adaptive schedule sizing, prefetch-efficacy counters."""

import json
import time

import numpy as np
import pytest

import repro.core as dl
from repro.core import fetch as fetchlib
from repro.core.manifest import (COMPAT_FORMATS, MANIFEST_KEY, ColumnStats,
                                 Manifest)
from repro.core.pipeline import ScanPipeline, derive_schedule_params
from repro.core.scheduler import CostModel
from repro.core.tql import parse, plan_where
from repro.core.views import DatasetView


def _build(storage=None, n=300, dims=64, n_tensors=2):
    """Clustered multi-tensor dataset, small chunks (pruning granularity)."""
    rng = np.random.default_rng(7)
    ds = dl.Dataset(storage)
    for j in range(n_tensors):
        ds.create_tensor(f"t{j}", dtype="float32", min_chunk_size=1 << 11,
                         max_chunk_size=1 << 12)
    for i in range(n):
        band = i // 50
        ds.append({f"t{j}": (rng.standard_normal(dims).astype(np.float32)
                             + np.float32(10 * band + j))
                   for j in range(n_tensors)})
    ds.commit("fixture")
    return ds


# --------------------------------------------------------------- plan-at-open
def test_plan_where_zero_binds_zero_requests_on_cold_open():
    """Acceptance: plan_where on a committed dataset produces verdicts
    straight from the 2-request cold open — no tensor binds, no further
    storage requests (the manifest's column-statistics section)."""
    base = dl.MemoryProvider()
    _build(base)
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    ds = dl.Dataset(s3)
    open_requests = s3.stats["requests"]
    assert open_requests <= 3  # cold-open budget

    view = DatasetView.full(ds)          # row count from the manifest too
    q = parse("SELECT * FROM dataset WHERE MIN(t0) > 20 AND t1 < 100")
    plan = plan_where(view, q.where)
    assert plan is not None and plan.effective
    assert len(plan.pruned) > 0
    assert s3.stats["requests"] == open_requests, \
        "planning issued storage requests"
    assert view._bound == {} and ds._tensors == {}, \
        "planning bound a tensor"
    assert plan.stats_coverage == 1.0


def test_manifest_v1_pointer_still_loads():
    """Backward compat: a v1 pointer/segment set (no column-statistics
    section) loads, plans via the tensor-bind fallback, identical rows."""
    base = dl.MemoryProvider()
    ds = _build(base)
    expect = ds.query("SELECT * FROM dataset WHERE MIN(t0) > 20")
    # rewrite the manifest as v1: drop stats sections + format markers
    ptr = json.loads(base.get(MANIFEST_KEY).decode())
    ptr["format"] = "deeplake-repro-manifest-v1"
    for seg_key in ptr["segments"]:
        seg = json.loads(base.get(seg_key).decode())
        seg["format"] = "deeplake-repro-manifest-v1"
        for node in seg["nodes"].values():
            node.pop("stats", None)
        base.put(seg_key, json.dumps(seg).encode())
    base.put(MANIFEST_KEY, json.dumps(ptr).encode())

    ds2 = dl.Dataset(base)
    assert ds2.manifest is not None
    assert ds2.vc.column_stats("t0") is None        # v1: no scan index
    got = ds2.query("SELECT * FROM dataset WHERE MIN(t0) > 20")
    assert got.indices.tolist() == expect.indices.tolist()


def test_column_stats_roundtrip_with_missing_records():
    cs = ColumnStats(last_idx=np.asarray([9, 19, 29], np.int64),
                     chunk_stats=[None, None, None])
    rt = ColumnStats.from_json(cs.to_json())
    assert rt.num_samples == 30 and rt.num_chunks == 3
    assert rt.stats_of(1) is None
    assert rt.ords_of([0, 9, 10, 29]).tolist() == [0, 0, 1, 2]
    with pytest.raises(IndexError):
        cs.ords_of([30])


def test_backfill_then_compaction_restores_plan_at_open():
    """Legacy pre-stats dataset: backfill + compaction must yield a
    manifest whose column-statistics section plans with zero binds."""
    base = dl.MemoryProvider()
    _build(base)
    # strip manifest + stats sidecars: simulate a pre-PR-1 dataset
    base.delete(MANIFEST_KEY)
    for key in list(base.list_keys("manifests/")):
        base.delete(key)
    for key in list(base.list_keys()):
        if key.endswith("chunk_stats.json"):
            base.delete(key)
    legacy = dl.Dataset(base)
    legacy.maintenance().backfill_stats()
    report = legacy.maintenance().compact_manifest()
    assert report.details["column_stats_lifted"] > 0

    ds = dl.Dataset(base)
    view = DatasetView.full(ds)
    plan = plan_where(view, parse(
        "SELECT * FROM dataset WHERE MIN(t0) > 20").where)
    assert plan is not None and len(plan.pruned) > 0
    assert view._bound == {} and ds._tensors == {}


# ------------------------------------------------------- streaming execution
QUERIES = [
    "SELECT * FROM dataset WHERE MIN(t0) > 20",
    "SELECT * FROM dataset WHERE t0 > 15 AND t1 < 41",
    "SELECT * FROM dataset WHERE MEAN(t0) + MEAN(t1) > 50",
    "SELECT * FROM dataset WHERE t0 != 3",
    "SELECT t0, MEAN(t1) AS m FROM dataset WHERE m > 30 ORDER BY m",
]


@pytest.mark.parametrize("use_stats", [True, False])
def test_streaming_results_byte_identical(use_stats):
    """Acceptance: TQL results byte-identical on both execution paths
    (streamed chunk groups vs whole-view column stack)."""
    ds = _build()
    for q in QUERIES:
        a = ds.query(q, use_stats=use_stats, stream=True)
        b = ds.query(q, use_stats=use_stats, stream=False)
        assert a.indices.tolist() == b.indices.tolist(), q
        for t in ("t0", "t1"):
            np.testing.assert_array_equal(a[t].numpy(), b[t].numpy())


def test_streaming_prefetches_ahead_one_request_per_chunk():
    """Verify-tail streaming: each consulted chunk is fetched at most
    once (whole-chunk prefetch, picked up by the group decode)."""
    base = dl.MemoryProvider()
    _build(base)
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    ds = dl.Dataset(s3)
    nchunks = sum(ds[t].num_chunks for t in ds.tensor_names)
    expect = _build().query("SELECT * FROM dataset WHERE MIN(t0) > 20",
                            use_stats=False).indices.tolist()
    s3.reset_stats()
    view = ds.query("SELECT * FROM dataset WHERE MIN(t0) > 20")
    assert view.indices.tolist() == expect
    assert s3.stats["requests"] <= nchunks
    eng = fetchlib.engine_for(s3)
    assert eng.stats["prefetch_hits"] > 0


def test_random_disables_streaming_and_matches_row_path():
    ds = _build(n=80)
    q = "SELECT * FROM dataset WHERE RANDOM() > 0.5"
    a = ds.query(q)            # auto mode must fall back to whole-view
    b = ds.query(q, stream=False)
    assert a.indices.tolist() == b.indices.tolist()


# ------------------------------------------------------- cross-unit prefetch
def _remote_loader_ds(n=200, chunk=1 << 12):
    base = dl.MemoryProvider()
    rng = np.random.default_rng(3)
    ds = dl.Dataset(base)
    ds.create_tensor("x", dtype="float32", min_chunk_size=chunk // 2,
                     max_chunk_size=chunk)
    ds.create_tensor("lab", htype="class_label")
    for i in range(n):
        ds.append({"x": rng.standard_normal(64).astype(np.float32),
                   "lab": np.int64(i)})
    ds.commit("c")
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    return dl.Dataset(s3), s3


def test_cross_unit_prefetch_spans_unit_boundaries():
    """The prefetch window must reach past the leading units: with a
    window deeper than one unit, chunks of later units are already in
    flight/resident when their workers start."""
    ds, s3 = _remote_loader_ds()
    loader = ds.dataloader(batch_size=16, num_workers=2, unit_size=8,
                           prefetch_units=6, seed=0)
    s3.reset_stats()
    labs = [int(v) for b in loader for v in b["lab"]]
    assert labs == list(range(200))
    eng = fetchlib.engine_for(ds.storage)
    assert eng.stats["prefetch_hits"] > 0
    # every chunk fetched ~once: prefetch + read dedup via the engine
    nchunks = ds["x"].num_chunks + ds["lab"].num_chunks
    assert s3.stats["requests"] <= nchunks + 2


def test_early_teardown_cancels_loader_prefetches():
    """Satellite: breaking out of iteration cancels the loader's queued
    prefetches (owner-scoped) and the loader stays re-iterable."""
    ds, s3 = _remote_loader_ds()
    loader = ds.dataloader(batch_size=8, num_workers=2, unit_size=4,
                           prefetch_units=8, seed=0)
    it = iter(loader)
    next(it)
    it.close()  # early teardown -> finally -> pipeline.close()
    eng = fetchlib.engine_for(ds.storage)
    deadline = time.time() + 5
    while time.time() < deadline:
        with eng._lock:
            mine = [k for k, (f, o) in eng._inflight.items() if o is loader]
        if not mine:
            break
        time.sleep(0.05)
    assert not mine, "loader-owned prefetches survived teardown"
    # the engine still serves other consumers and the loader re-iterates
    labs = [int(v) for b in loader for v in b["lab"]]
    assert sorted(labs) == list(range(200))


def test_prefetch_window_never_evicts_own_staged_blobs():
    """Satellite: the byte-bounded window must stage at most half the
    resident store, so its own later prefetches never evict staged,
    still-unconsumed blobs (prefetch_wasted_bytes stays 0)."""
    ds, s3 = _remote_loader_ds(n=400, chunk=1 << 13)
    eng = fetchlib.engine_for(ds.storage)
    eng.resident_bytes = 64 << 10   # tiny store: whole scan won't fit
    loader = ds.dataloader(batch_size=16, num_workers=2, unit_size=8,
                           prefetch_units=16, seed=0)
    labs = [int(v) for b in loader for v in b["lab"]]
    assert sorted(labs) == list(range(400))
    assert eng.stats["prefetch_hits"] > 0
    assert eng.stats["prefetch_wasted_bytes"] == 0


# ----------------------------------------------------------- epoch behaviour
def test_epoch_reshuffle_seed_determinism():
    """Satellite: (seed, epoch) fully determines the order plan — two
    fresh loaders replay identical epochs; consecutive epochs differ."""
    ds, _ = _remote_loader_ds(n=120)
    mk = lambda: ds.dataloader(batch_size=8, shuffle=True, num_workers=4,
                               unit_size=8, seed=11)
    a, b = mk(), mk()
    plans_a = [a._plan(np.random.default_rng(11 + e)) for e in range(3)]
    plans_b = [b._plan(np.random.default_rng(11 + e)) for e in range(3)]
    assert plans_a == plans_b
    assert plans_a[0] != plans_a[1] != plans_a[2]
    # full iteration: same multiset each epoch, deterministic sequential
    seq = ds.dataloader(batch_size=8, shuffle=False, num_workers=4, seed=11)
    e1 = [int(v) for bt in seq for v in bt["lab"]]
    e2 = [int(v) for bt in seq for v in bt["lab"]]
    assert e1 == e2 == list(range(120))
    sh1 = [int(v) for bt in a for v in bt["lab"]]
    sh2 = [int(v) for bt in a for v in bt["lab"]]
    assert sorted(sh1) == sorted(sh2) == list(range(120))
    assert sh1 != sh2


# -------------------------------------------------------- adaptive schedule
def test_adaptive_schedule_params_derive_from_cost_model():
    ds, _ = _remote_loader_ds()
    loader = ds.dataloader(batch_size=16)       # adaptive defaults
    us, pf = loader._schedule_params()
    lo_u, hi_u = CostModel.UNIT_SIZE_BOUNDS
    lo_p, hi_p = CostModel.PREFETCH_UNIT_BOUNDS
    assert lo_u <= us <= hi_u and lo_p <= pf <= hi_p
    # 30ms x 50MB/s => ~1.5MB per unit; 64-float samples = 256B payload
    assert us > 16, "remote schedule should exceed the local default"
    # explicit values always win
    pinned = ds.dataloader(batch_size=16, unit_size=5, prefetch_units=3)
    assert pinned._schedule_params() == (5, 3)


def test_local_providers_keep_fixed_defaults():
    base = dl.MemoryProvider()
    ds = _build(base, n=40)
    loader = ds.dataloader(batch_size=8)
    assert loader._schedule_params() == (16, 8)


def test_derive_params_respects_memory_budget():
    cm = CostModel()
    eng = fetchlib.FetchEngine(dl.SimulatedS3Provider(time_scale=0))
    us, pf = derive_schedule_params(eng, cm, sample_bytes=1 << 20,
                                    memory_budget_bytes=8 << 20)
    assert us * (1 << 20) * pf <= 8 << 20 or pf == CostModel.PREFETCH_UNIT_BOUNDS[0]


# -------------------------------------------------------------- io reporting
def test_provider_snapshot_includes_engine_counters():
    from benchmarks import io_report
    base = dl.MemoryProvider()
    _build(base)
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    ds = dl.Dataset(s3)
    ds.query("SELECT * FROM dataset WHERE MIN(t0) > 20")
    snap = io_report.provider_snapshot(s3)
    assert "engine_prefetch_hits" in snap
    assert "engine_prefetch_wasted_bytes" in snap
    assert snap["requests"] > 0
