"""Sharded query serving (core/serving.py) + fair multi-tenant fetch.

Covers the PR-9 contract: an N-thread query storm returns byte-identical
results to the serial path, per-tenant staging budgets actually bound one
tenant's footprint, any commit rolls the result/plan cache key, the
shard-parallel top-k scan stays byte-identical (NaN keys included), and
owner-scoped cancellation never drops another tenant's in-flight blobs.
"""

import threading

import numpy as np
import pytest

import repro.core as dl
from repro.core import telemetry
from repro.core.fetch import FetchEngine, engine_for
from repro.core.pipeline import ScanPipeline
from repro.core.serving import QueryService


def _make_ds(n=400, bands=True, seed=0, chunk=1 << 11):
    prov = dl.SimulatedS3Provider(time_scale=0)
    ds = dl.Dataset(prov)
    ds.create_tensor("val", dtype="float32", min_chunk_size=chunk // 2,
                     max_chunk_size=chunk)
    ds.create_tensor("label", dtype="int32")
    rng = np.random.default_rng(seed)
    for i in range(n):
        v = rng.standard_normal(16).astype(np.float32)
        if bands:
            v += np.float32(10 * (i // (n // 8)))
        ds.append({"val": v, "label": np.int32(i % 7)})
    ds.commit("seed")
    return ds, prov


# ------------------------------------------------------------ query storm
def test_query_storm_parity_vs_serial():
    """8 threads x same committed query: every result byte-identical to
    the serial dataset.query, and the storm costs at most 2x one client's
    provider requests (single-flight + result cache)."""
    ds, prov = _make_ds()
    q = "SELECT * WHERE label == 3 AND MAX(val) > 20"
    expect = ds.query(q, stream=False).indices.tolist()

    svc = QueryService(ds, max_concurrent=4, shards=2)
    prov.reset_stats()
    assert svc.query(q).indices.tolist() == expect
    one_client = prov.stats["requests"]

    svc2 = QueryService(ds, max_concurrent=4, shards=2)
    svc2.clear_cache()
    prov.reset_stats()
    results, errors = [None] * 8, []

    def client(i):
        try:
            results[i] = svc2.query(q, tenant=f"t{i % 2}").indices.tolist()
        except Exception as e:  # pragma: no cover - diagnostic
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errors
    for r in results:
        assert r == expect
    assert prov.stats["requests"] <= max(2 * one_client, one_client + 2)
    st = svc2.stats()
    assert st["queries"] == 8
    assert st["cache_misses"] == 1          # single-flight: one leader
    assert st["cache_hits"] == 7            # every follower served cached


def test_distinct_queries_storm_parity():
    ds, _ = _make_ds()
    svc = QueryService(ds, max_concurrent=3, shards=2)
    queries = [f"SELECT * WHERE label == {k}" for k in range(6)]
    expect = [ds.query(q).indices.tolist() for q in queries]
    results, errors = [None] * 6, []

    def client(i):
        try:
            results[i] = svc.query(queries[i], tenant=f"t{i}").indices.tolist()
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errors
    assert results == expect


# ------------------------------------------------------------ cache keying
def test_cache_hit_zero_requests_zero_planner_work():
    ds, prov = _make_ds()
    svc = QueryService(ds)
    q = "SELECT * WHERE MIN(val) > 35 ORDER BY label LIMIT 20"
    first = svc.query(q)
    prov.reset_stats()
    plans0 = telemetry.registry().snapshot().get("tql_plans", 0)
    again = svc.query(q)
    assert again.indices.tolist() == first.indices.tolist()
    assert prov.stats["requests"] == 0
    assert telemetry.registry().snapshot().get("tql_plans", 0) == plans0
    assert svc.stats()["cache_hits"] == 1
    # normalization: same query, different spelling, same entry
    assert svc.query(q.replace(" WHERE", "   where")).indices.tolist() \
        == first.indices.tolist()
    assert svc.stats()["cache_hits"] == 2


def test_commit_rolls_cache_key():
    ds, _ = _make_ds(n=100, bands=False)
    svc = QueryService(ds)
    q = "SELECT * WHERE label == 2"
    before = svc.query(q)
    assert svc.query(q).indices.tolist() == before.indices.tolist()
    assert svc.stats()["cache_hits"] == 1
    # dirty head: results reflect the new row and are never cached
    ds.append({"val": np.zeros(16, np.float32), "label": np.int32(2)})
    mid = svc.query(q)
    assert len(mid) == len(before) + 1
    assert svc.stats()["uncacheable"] == 1
    # commit publishes a new manifest segment -> old entry unreachable
    ds.commit("one more row")
    after = svc.query(q)
    assert after.indices.tolist() == mid.indices.tolist()
    assert svc.stats()["cache_misses"] >= 2
    # and the post-commit entry is itself served from cache
    hits = svc.stats()["cache_hits"]
    assert svc.query(q).indices.tolist() == after.indices.tolist()
    assert svc.stats()["cache_hits"] == hits + 1


def test_version_pinned_query_cacheable_on_dirty_head():
    ds, _ = _make_ds(n=100, bands=False)
    node = ds.vc.resolve_ref(ds.vc.current.parent or ds.vc.current.id)
    q = f'SELECT * FROM dataset VERSION "{node}" WHERE label == 1'
    svc = QueryService(ds)
    pinned = svc.query(q)
    ds.append({"val": np.zeros(16, np.float32), "label": np.int32(1)})
    again = svc.query(q)   # dirty head, but the pinned node is sealed
    assert again.indices.tolist() == pinned.indices.tolist()
    assert svc.stats()["cache_hits"] == 1


# ------------------------------------------------------- tenant isolation
def _evict_all(ds):
    """Drop every chunk blob from the engine so prefetches really stage."""
    eng = engine_for(ds.storage)
    for name in ds.tensor_names:
        t = ds._tensor(name)
        for nm in t.encoder.chunk_names():
            eng.discard(t._chunk_key(nm))


def test_tenant_budget_bounds_staging_and_throttles():
    ds, _ = _make_ds(n=800, chunk=1 << 12)
    eng = engine_for(ds.storage)
    budget = 2 << 12   # room for ~2 chunks of prefetch staging
    eng.register_tenant("small", byte_budget=budget)
    _evict_all(ds)
    svc = QueryService(ds, max_concurrent=2)
    # use_stats=False forces the streamed per-chunk-group WHERE over the
    # many-chunk val tensor, so the tenant's prefetch window actually
    # exercises the staging budget
    out = svc.query("SELECT * WHERE MAX(val) > -1000", tenant="small",
                    stream=True, use_stats=False)
    assert len(out) == 800
    st = eng.tenant_stats("small")
    assert st["prefetch_requests"] > 0
    assert st["staged_peak_bytes"] <= budget
    assert st["throttle_events"] > 0       # the budget actually pushed back
    # an unbudgeted tenant on the same engine is not throttled
    svc.clear_cache()
    _evict_all(ds)
    out2 = svc.query("SELECT * WHERE MIN(val) > -1000", tenant="big",
                     stream=True, use_stats=False)
    assert len(out2) == 800
    assert eng.tenant_stats("big")["throttle_events"] == 0


# --------------------------------------------------------- sharded top-k
@pytest.mark.parametrize("desc", [False, True])
def test_sharded_topk_byte_parity_with_nans(desc):
    prov = dl.SimulatedS3Provider(time_scale=0)
    ds = dl.Dataset(prov)
    ds.create_tensor("key", dtype="float32", min_chunk_size=1 << 9,
                     max_chunk_size=1 << 10)
    rng = np.random.default_rng(3)
    for i in range(600):
        v = np.float32(rng.standard_normal() + 5 * (i // 75))
        if i % 37 == 0:
            v = np.float32("nan")
        ds.append({"key": v})
    ds.commit("c")
    order = "DESC" if desc else "ASC"
    q = f"SELECT * ORDER BY key {order} LIMIT 25"
    legacy = ds.query(q, stream=False)
    sharded = dl.Dataset(prov).query(q, shards=4)
    assert sharded.indices.tolist() == legacy.indices.tolist()
    assert sharded.topk_plan["shards"] == 4
    # sharded early termination still fires: not every group was scanned
    if sharded.topk_plan.get("terminated_early"):
        assert sharded.topk_plan["groups_scanned"] \
            < sharded.topk_plan["groups"]


def test_sharded_where_parity_and_shard_spans():
    ds, _ = _make_ds()
    q = "SELECT * WHERE MAX(val) > 30 AND label != 5"
    expect = ds.query(q, stream=False).indices.tolist()
    with telemetry.tracing() as tr:
        got = ds.query(q, shards=3)
    assert got.indices.tolist() == expect
    assert tr.count("serve.shard[") > 0


# ------------------------------------------------- owner-scoped cancel fix
def test_owner_scoped_cancel_keeps_shared_inflight_blob():
    """Regression: cancelling tenant A's pending prefetches must not drop
    a blob tenant B is also waiting on (shared in-flight entry)."""
    gate, started = threading.Event(), threading.Event()

    class Gated(dl.MemoryProvider):
        def get(self, key):
            started.set()
            gate.wait(timeout=5)
            return super().get(key)

    p = Gated()
    p.put("shared", b"v" * 64)
    p.put("queued", b"w" * 64)
    eng = FetchEngine(p, max_workers=1)
    try:
        fa = eng.prefetch("shared", owner="A")
        assert started.wait(timeout=5)
        fb = eng.prefetch("shared", owner="B")   # dedup joins the entry
        assert fb is fa
        # the queued key (worker busy) is also co-owned
        fq = eng.prefetch("queued", owner="A")
        eng.prefetch("queued", owner="B")
        eng.cancel_pending("A")                  # A tears down its pipeline
        assert not fa.cancelled()                # B still owns both
        assert not fq.cancelled()
        gate.set()
        assert fa.result(timeout=5) == b"v" * 64
        assert fq.result(timeout=5) == b"w" * 64
        assert eng.resident("shared") == b"v" * 64
        # now B goes away too: sole-owner cancel may drop queued work
        f2 = eng.prefetch("q2", owner="B")
        del f2
        eng.cancel_pending("B")
    finally:
        gate.set()
        eng.close()


def test_two_interleaved_pipelines_one_engine():
    """Closing pipeline A mid-stream (owner-scoped cancel) must leave
    pipeline B's stream byte-identical."""
    ds, _ = _make_ds(n=600)
    view = dl.DatasetView.full(ds)
    expect = [v.tolist() for v in ds._tensor("val").read_batch(
        np.arange(600))]
    pa = ScanPipeline.for_query(view, ["val"], owner="A")
    pb = ScanPipeline.for_query(view, ["val"], owner="B")
    ga, gb = pa.stream(), pb.stream()
    next(ga)          # A starts prefetching ahead
    got = {}
    for i, (positions, sub) in enumerate(gb):
        if i == 1:
            pa.close()     # A cancels ITS pending prefetches mid-flight
        vals = sub.tensor("val").numpy()
        for p, v in zip(positions, vals):
            got[int(p)] = np.asarray(v).tolist()
    assert len(got) == 600
    for i in range(600):
        assert got[i] == expect[i]
