"""Unit + property tests for the storage format (C1): providers, codecs,
chunks, chunk encoder, tiling, tensors."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as dl
from repro.core import chunks as chunklib
from repro.core.chunk_encoder import ChunkEncoder
from repro.core.codecs import available as available_codecs, get_codec
from repro.core.tiling import (TileDescriptor, assemble_from_tiles,
                               plan_tile_shape, split_into_tiles,
                               tiles_for_region, assemble_region)


# ---------------------------------------------------------------- storage
def test_memory_provider_roundtrip():
    p = dl.MemoryProvider()
    p.put("a/b", b"hello")
    assert p.get("a/b") == b"hello"
    assert p.get_range("a/b", 1, 3) == b"el"
    assert p.list_keys("a/") == ["a/b"]
    p.delete("a/b")
    assert not p.exists("a/b")
    with pytest.raises(dl.StorageError):
        p.get("a/b")


def test_local_provider_roundtrip(tmp_path):
    p = dl.LocalProvider(str(tmp_path))
    p.put("x/y/z.bin", b"0123456789")
    assert p.get("x/y/z.bin") == b"0123456789"
    assert p.get_range("x/y/z.bin", 2, 5) == b"234"
    assert p.num_bytes("x/y/z.bin") == 10
    assert p.list_keys() == ["x/y/z.bin"]


def test_simulated_s3_accounting():
    s3 = dl.SimulatedS3Provider(time_scale=0, latency_s=0.01,
                                bandwidth_bps=1e6)
    s3.put("k", b"x" * 1000)
    s3.get("k")
    s3.get_range("k", 0, 100)
    assert s3.stats["requests"] == 3
    assert s3.stats["bytes_down"] == 1100
    assert s3.stats["bytes_up"] == 1000
    # 3 * latency + traffic/bandwidth
    assert abs(s3.stats["sim_seconds"] - (0.03 + 2100 / 1e6)) < 1e-9


def test_lru_cache_hits_and_eviction():
    base = dl.MemoryProvider()
    lru = dl.LRUCacheProvider(base, capacity_bytes=250)
    for i in range(4):
        lru.put(f"k{i}", bytes(100))
    lru.get("k3")
    lru.get("k3")
    assert lru.hits >= 1
    # capacity 250 -> at most 2 resident
    assert lru._size <= 250
    assert lru.get("k0") == bytes(100)  # served from base after eviction


def test_chain():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    c = dl.chain(dl.MemoryProvider(), s3, capacity_bytes=1 << 20)
    c.put("a", b"abc")
    before = s3.stats["requests"]
    assert c.get("a") == b"abc"      # cache hit: no s3 round trip
    assert s3.stats["requests"] == before


# ----------------------------------------------------------------- codecs
@pytest.mark.parametrize("codec", ["raw", "zlib", "lzma"])
@pytest.mark.parametrize("dtype", ["uint8", "int32", "float32", "float64"])
def test_codec_lossless_roundtrip(codec, dtype, rng):
    c = get_codec(codec)
    arr = (rng.standard_normal((7, 13)) * 100).astype(dtype)
    out = c.decode(c.encode(arr), arr.shape, arr.dtype)
    np.testing.assert_array_equal(out, arr)


def test_quant8_lossy_bounded(rng):
    c = get_codec("quant8")
    arr = rng.standard_normal((32, 32)).astype(np.float32)
    out = c.decode(c.encode(arr), arr.shape, arr.dtype)
    span = arr.max() - arr.min()
    assert np.max(np.abs(out - arr)) <= span / 255 + 1e-6
    # uint8 images roundtrip exactly
    img = rng.integers(0, 255, (8, 8, 3), dtype=np.uint8)
    np.testing.assert_array_equal(
        c.decode(c.encode(img), img.shape, img.dtype), img)


# ----------------------------------------------------------------- chunks
@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 10), st.integers(1, 10)),
                min_size=1, max_size=12),
       st.sampled_from(["raw", "zlib"]))
def test_chunk_roundtrip_property(shapes, codec):
    rng = np.random.default_rng(1)
    b = chunklib.ChunkBuilder("<f4", codec)
    samples = []
    for shp in shapes:
        arr = rng.standard_normal(shp).astype(np.float32)
        samples.append(arr)
        b.append_array(arr)
    raw = b.serialize()
    assert len(raw) == b.nbytes_serialized()
    out = chunklib.read_all_samples(raw)
    assert len(out) == len(samples)
    for got, want in zip(out, samples):
        np.testing.assert_array_equal(got, want)


def test_chunk_byte_ranges_match_range_reads():
    b = chunklib.ChunkBuilder("<i4", "raw")
    arrs = [np.arange(i + 1, dtype=np.int32) for i in range(5)]
    for a in arrs:
        b.append_array(a)
    raw = b.serialize()
    h = chunklib.parse_header(raw)
    assert h.header_size == chunklib.header_size_of(raw[:48])
    for i, a in enumerate(arrs):
        s, e = h.byte_range(i)
        got = chunklib.decode_sample(h, raw[s:e], i)
        np.testing.assert_array_equal(got, a)


# ------------------------------------------------------------ chunk encoder
@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 20), min_size=1, max_size=30))
def test_encoder_lookup_property(counts):
    enc = ChunkEncoder()
    for i, c in enumerate(counts):
        enc.register_chunk(f"c{i}", c)
    assert enc.num_samples == sum(counts)
    # every global index maps to the right (chunk, local)
    gidx = 0
    for i, c in enumerate(counts):
        for local in range(c):
            name, l = enc.lookup(gidx)
            assert name == f"c{i}" and l == local
            gidx += 1
    # serialize roundtrip
    enc2 = ChunkEncoder.deserialize(enc.serialize())
    assert enc2.chunk_names() == enc.chunk_names()
    assert enc2.num_samples == enc.num_samples


def test_encoder_scale_is_compact():
    enc = ChunkEncoder()
    for i in range(10_000):
        enc.register_chunk(f"c{i:08x}", 1000)
    # paper §3.4: ~150MB per 1PB; here: <30 bytes/chunk in memory
    assert enc.nbytes() / enc.num_chunks < 30
    assert enc.lookup(9_999_999) == ("c0000270f", 999)


# ----------------------------------------------------------------- tiling
def test_tile_planning_fits_budget():
    shape = plan_tile_shape((1000, 1000, 3), 1, 64 << 10)
    assert int(np.prod(shape)) <= 64 << 10


@settings(max_examples=20, deadline=None)
@given(st.integers(30, 120), st.integers(30, 120), st.integers(1, 3))
def test_tiling_reassembles(h, w, c):
    rng = np.random.default_rng(2)
    arr = rng.integers(0, 255, (h, w, c), dtype=np.uint8)
    tile_shape = plan_tile_shape(arr.shape, 1, 1 << 10)
    grid, tiles = split_into_tiles(arr, tile_shape)
    codec = get_codec("raw")
    desc = TileDescriptor(arr.shape, tile_shape, grid,
                          [f"t{i}" for i in range(len(tiles))], "|u1", "raw")
    payloads = [codec.encode(t) for t in tiles]
    np.testing.assert_array_equal(assemble_from_tiles(desc, payloads), arr)
    region = (slice(h // 4, h // 2), slice(w // 3, w - 1))
    need = tiles_for_region(desc, region)
    sub = assemble_region(desc, region, {i: payloads[i] for i in need})
    np.testing.assert_array_equal(sub, arr[region])
    assert len(need) <= len(tiles)


# ----------------------------------------------------------------- tensors
@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 6), st.integers(1, 6)), min_size=1,
                max_size=25),
       st.sampled_from(["raw", "zlib"]),
       st.integers(6, 10))
def test_tensor_append_read_property(shapes, codec, log_max_chunk):
    rng = np.random.default_rng(3)
    ds = dl.dataset()
    max_chunk = 1 << log_max_chunk
    t = ds.create_tensor("x", dtype="float32", sample_compression=codec,
                         min_chunk_size=max_chunk // 2, max_chunk_size=max_chunk)
    arrs = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    for a in arrs:
        t.append(a)
    ds.flush()
    for i, a in enumerate(arrs):
        np.testing.assert_array_equal(t.read(i), a)
        assert t.shape_of(i) == a.shape
    # reload from storage (fresh dataset object)
    ds2 = dl.Dataset(ds.storage)
    t2 = ds2["x"]
    assert len(t2) == len(arrs)
    for i, a in enumerate(arrs):
        np.testing.assert_array_equal(t2.read(i), a)


def test_tensor_update_and_sparse_assignment():
    ds = dl.dataset()
    t = ds.create_tensor("x", dtype="int32", strict=False,
                         min_chunk_size=64, max_chunk_size=256)
    for i in range(10):
        t.append(np.full((4,), i, np.int32))
    t[3] = np.full((4,), 99, np.int32)
    np.testing.assert_array_equal(t.read(3), np.full((4,), 99, np.int32))
    t[15] = np.full((4,), 7, np.int32)   # out-of-bounds: §3.5 sparse assign
    assert len(t) == 16
    assert t.read(12).size == 0
    np.testing.assert_array_equal(t.read(15), np.full((4,), 7, np.int32))


def test_tensor_strict_mode_rejects():
    ds = dl.dataset()
    t = ds.create_tensor("img", htype="image")
    with pytest.raises(ValueError):
        t.append(np.zeros((4,), np.uint8))      # wrong ndim for image
    with pytest.raises(IndexError):
        t[5] = np.zeros((2, 2, 3), np.uint8)    # strict: no sparse assign


def test_tensor_tiled_large_sample():
    ds = dl.dataset()
    t = ds.create_tensor("big", dtype="float32", min_chunk_size=1 << 10,
                         max_chunk_size=1 << 12)
    rng = np.random.default_rng(4)
    big = rng.standard_normal((80, 80)).astype(np.float32)  # 25KB > 4KB max
    small = rng.standard_normal((4, 4)).astype(np.float32)
    t.append(big)
    t.append(small)
    ds.flush()
    np.testing.assert_array_equal(t.read(0), big)
    np.testing.assert_array_equal(t.read(1), small)
    region = t.read_region(0, (slice(10, 30), slice(60, 79)))
    np.testing.assert_array_equal(region, big[10:30, 60:79])


def test_rechunk_preserves_data_and_bounds():
    ds = dl.dataset()
    t = ds.create_tensor("x", dtype="int32", min_chunk_size=1 << 10,
                         max_chunk_size=1 << 12)
    arrs = [np.full((100,), i, np.int32) for i in range(40)]
    for a in arrs:
        t.append(a)
    # force fragmentation via updates
    for i in range(0, 40, 5):
        t[i] = np.full((100,), -i, np.int32)
    n = t.rechunk()
    assert n == t.num_chunks
    for i in range(40):
        want = -i if i % 5 == 0 else i
        np.testing.assert_array_equal(t.read(i), np.full((100,), want, np.int32))
