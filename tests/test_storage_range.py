"""get_range boundary semantics across providers — the streaming primitive
the scan planner leans on: end past object length clamps, zero-length reads
return b"" without raising, LRU chains serve ranges from cached objects."""

import numpy as np
import pytest

import repro.core as dl

PAYLOAD = b"0123456789"  # 10 bytes


def _providers(tmp_path):
    return {
        "memory": dl.MemoryProvider(),
        "local": dl.LocalProvider(str(tmp_path)),
        "s3sim": dl.SimulatedS3Provider(time_scale=0),
    }


@pytest.fixture(params=["memory", "local", "s3sim"])
def provider(request, tmp_path):
    p = _providers(tmp_path)[request.param]
    p.put("obj", PAYLOAD)
    return p


def test_interior_range(provider):
    assert provider.get_range("obj", 2, 5) == b"234"


def test_end_past_object_length_clamps(provider):
    assert provider.get_range("obj", 8, 100) == b"89"
    assert provider.get_range("obj", 0, 10_000) == PAYLOAD


def test_zero_length_read(provider):
    assert provider.get_range("obj", 3, 3) == b""
    assert provider.get_range("obj", 0, 0) == b""


def test_start_at_or_past_end(provider):
    assert provider.get_range("obj", 10, 20) == b""
    assert provider.get_range("obj", 50, 60) == b""


def test_inverted_range_is_empty(provider):
    assert provider.get_range("obj", 7, 3) == b""


def test_full_range_roundtrip(provider):
    assert provider.get_range("obj", 0, len(PAYLOAD)) == PAYLOAD


def test_missing_key_raises(provider):
    with pytest.raises(dl.StorageError):
        provider.get_range("nope", 0, 4)


# ------------------------------------------------------------- s3 accounting
def test_s3_range_request_accounting():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    s3.put("obj", PAYLOAD)
    s3.reset_stats()
    s3.get_range("obj", 2, 5)
    s3.get_range("obj", 8, 100)    # clamped: charges 2 bytes, not 92
    s3.get_range("obj", 3, 3)      # zero-length still costs a request
    assert s3.stats["requests"] == 3
    assert s3.stats["ranged_requests"] == 3
    assert s3.stats["bytes_down"] == 3 + 2 + 0


# --------------------------------------------------------------- LRU chains
def test_lru_serves_ranges_from_cached_object():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    lru = dl.LRUCacheProvider(s3, capacity_bytes=1 << 10)
    lru.put("obj", PAYLOAD)        # write-through fills the cache
    s3.reset_stats()
    assert lru.get_range("obj", 2, 5) == b"234"
    assert lru.get_range("obj", 8, 100) == b"89"
    assert lru.get_range("obj", 4, 4) == b""
    assert s3.stats["requests"] == 0   # all hits, base never touched
    assert lru.hits >= 3


def test_lru_range_misses_pass_through_without_filling():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    lru = dl.LRUCacheProvider(s3, capacity_bytes=1 << 10)
    s3.base.put("cold", PAYLOAD)   # only in the base tier
    assert lru.get_range("cold", 0, 4) == b"0123"
    assert lru.misses == 1
    # streaming reads must not fill the cache (no eviction pressure)
    assert lru.get_range("cold", 0, 4) == b"0123"
    assert lru.misses == 2
    assert s3.stats["ranged_requests"] == 2


def test_chain_helper_builds_lru_over_s3():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    chained = dl.chain(dl.MemoryProvider(), s3, capacity_bytes=1 << 10)
    chained.put("obj", PAYLOAD)
    s3.reset_stats()
    assert chained.get_range("obj", 0, 100) == PAYLOAD
    assert s3.stats["requests"] == 0


def test_ranges_match_full_get_suffixes(provider):
    """get_range(k, s, e) == get(k)[s:e] for every boundary combination."""
    full = provider.get("obj")
    for s in (0, 1, 5, 9, 10, 15):
        for e in (0, 1, 5, 10, 11, 100):
            assert provider.get_range("obj", s, e) == full[s:e], (s, e)


# --------------------------------------------------- batched reads (get_ranges)
# boundary cases: adjacent, overlapping, gap, tail-clamped, zero-length,
# inverted, past-the-end, duplicates, unsorted input
BOUNDARY_RANGE_SETS = [
    [(0, 3), (3, 6)],                      # adjacent: must merge cleanly
    [(0, 5), (3, 8)],                      # overlapping
    [(0, 2), (8, 10)],                     # interior gap
    [(8, 100)],                            # tail-clamped
    [(3, 3), (0, 0), (10, 10)],            # zero-length only
    [(7, 3)],                              # inverted -> b""
    [(10, 20), (50, 60)],                  # entirely past the end
    [(2, 5), (2, 5), (2, 5)],              # duplicates
    [(6, 9), (0, 2), (4, 5)],              # unsorted input order
    [(0, 4), (4, 4), (4, 10), (9, 100)],   # mixed everything
]


@pytest.mark.parametrize("ranges", BOUNDARY_RANGE_SETS)
def test_get_ranges_equals_per_range_calls(provider, ranges):
    """Coalescing equivalence: get_ranges payloads are byte-identical to
    one get_range call per requested range, in input order."""
    want = [provider.get_range("obj", s, e) for s, e in ranges]
    assert provider.get_ranges("obj", ranges) == want


def test_get_ranges_empty_list_is_free(provider):
    assert provider.get_ranges("obj", []) == []
    assert provider.get_ranges("missing-key", []) == []  # not even validated


def test_get_ranges_missing_key_raises(provider):
    with pytest.raises(dl.StorageError):
        provider.get_ranges("nope", [(0, 4)])
    with pytest.raises(dl.StorageError):
        provider.get_ranges("nope", [(3, 3)])  # zero-length still validates


def test_get_many_matches_individual_gets(provider):
    provider.put("obj2", b"abc")
    out = provider.get_many(["obj", "obj2", "obj"])  # duplicate deduped
    assert out == {"obj": PAYLOAD, "obj2": b"abc"}
    with pytest.raises(dl.StorageError):
        provider.get_many(["obj", "nope"])


def test_coalesce_ranges_helper():
    spans, assign = dl.coalesce_ranges([(0, 3), (3, 6), (10, 12)], gap=0)
    assert spans == [(0, 6), (10, 12)]
    assert assign == [0, 0, 1]
    # the gap threshold bridges near ranges but not far ones
    spans, _ = dl.coalesce_ranges([(0, 2), (5, 7), (30, 31)], gap=3)
    assert spans == [(0, 7), (30, 31)]
    # inverted ranges are zero-length at start; input order is preserved
    spans, assign = dl.coalesce_ranges([(9, 2), (0, 1)], gap=100)
    assert spans == [(0, 9)]
    assert assign == [0, 0]


def test_s3_get_ranges_charges_one_request_per_coalesced_span():
    s3 = dl.SimulatedS3Provider(time_scale=0)   # threshold >> object size
    s3.put("obj", PAYLOAD)
    s3.reset_stats()
    out = s3.get_ranges("obj", [(0, 2), (4, 6), (8, 10)])
    assert out == [b"01", b"45", b"89"]
    assert s3.stats["requests"] == 1            # one span covers all three
    assert s3.stats["coalesced_requests"] == 1
    assert s3.stats["batched_ranges"] == 3
    assert s3.stats["bytes_down"] == 10         # gap bytes are downloaded


def test_s3_get_ranges_respects_gap_threshold():
    # threshold = latency * bandwidth = 0.01 * 100 = 1 byte
    s3 = dl.SimulatedS3Provider(time_scale=0, latency_s=0.01,
                                bandwidth_bps=100)
    s3.put("obj", PAYLOAD)
    assert s3.gap_threshold() == 1
    s3.reset_stats()
    out = s3.get_ranges("obj", [(0, 2), (3, 5), (8, 10)])  # gaps: 1, 3
    assert out == [b"01", b"34", b"89"]
    assert s3.stats["coalesced_requests"] == 2  # (0,5) merged, (8,10) apart
    assert s3.stats["bytes_down"] == 5 + 2


def test_s3_metadata_requests_are_charged():
    """exists/num_bytes are zero-byte round-trips, not free (§2.3: request
    count dominates object-store cost)."""
    s3 = dl.SimulatedS3Provider(time_scale=0)
    s3.put("obj", PAYLOAD)
    s3.reset_stats()
    assert s3.exists("obj")
    assert not s3.exists("nope")
    assert s3.num_bytes("obj") == 10
    assert s3.stats["requests"] == 3
    assert s3.stats["meta_requests"] == 3
    assert s3.stats["bytes_down"] == 0
    assert s3.stats["sim_seconds"] == pytest.approx(3 * s3.latency_s)


def test_lru_get_ranges_served_from_cached_object():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    lru = dl.LRUCacheProvider(s3, capacity_bytes=1 << 10)
    lru.put("obj", PAYLOAD)
    s3.reset_stats()
    assert lru.get_ranges("obj", [(0, 2), (5, 100), (3, 3)]) == \
        [b"01", b"56789", b""]
    assert s3.stats["requests"] == 0
    # a miss passes through batched without filling the cache
    s3.base.put("cold", PAYLOAD)
    assert lru.get_ranges("cold", [(0, 2), (4, 6)]) == [b"01", b"45"]
    assert s3.stats["coalesced_requests"] == 1
    assert lru.get_many(["obj", "cold"]) == {"obj": PAYLOAD, "cold": PAYLOAD}
