"""get_range boundary semantics across providers — the streaming primitive
the scan planner leans on: end past object length clamps, zero-length reads
return b"" without raising, LRU chains serve ranges from cached objects."""

import numpy as np
import pytest

import repro.core as dl

PAYLOAD = b"0123456789"  # 10 bytes


def _providers(tmp_path):
    return {
        "memory": dl.MemoryProvider(),
        "local": dl.LocalProvider(str(tmp_path)),
        "s3sim": dl.SimulatedS3Provider(time_scale=0),
    }


@pytest.fixture(params=["memory", "local", "s3sim"])
def provider(request, tmp_path):
    p = _providers(tmp_path)[request.param]
    p.put("obj", PAYLOAD)
    return p


def test_interior_range(provider):
    assert provider.get_range("obj", 2, 5) == b"234"


def test_end_past_object_length_clamps(provider):
    assert provider.get_range("obj", 8, 100) == b"89"
    assert provider.get_range("obj", 0, 10_000) == PAYLOAD


def test_zero_length_read(provider):
    assert provider.get_range("obj", 3, 3) == b""
    assert provider.get_range("obj", 0, 0) == b""


def test_start_at_or_past_end(provider):
    assert provider.get_range("obj", 10, 20) == b""
    assert provider.get_range("obj", 50, 60) == b""


def test_inverted_range_is_empty(provider):
    assert provider.get_range("obj", 7, 3) == b""


def test_full_range_roundtrip(provider):
    assert provider.get_range("obj", 0, len(PAYLOAD)) == PAYLOAD


def test_missing_key_raises(provider):
    with pytest.raises(dl.StorageError):
        provider.get_range("nope", 0, 4)


# ------------------------------------------------------------- s3 accounting
def test_s3_range_request_accounting():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    s3.put("obj", PAYLOAD)
    s3.reset_stats()
    s3.get_range("obj", 2, 5)
    s3.get_range("obj", 8, 100)    # clamped: charges 2 bytes, not 92
    s3.get_range("obj", 3, 3)      # zero-length still costs a request
    assert s3.stats["requests"] == 3
    assert s3.stats["ranged_requests"] == 3
    assert s3.stats["bytes_down"] == 3 + 2 + 0


# --------------------------------------------------------------- LRU chains
def test_lru_serves_ranges_from_cached_object():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    lru = dl.LRUCacheProvider(s3, capacity_bytes=1 << 10)
    lru.put("obj", PAYLOAD)        # write-through fills the cache
    s3.reset_stats()
    assert lru.get_range("obj", 2, 5) == b"234"
    assert lru.get_range("obj", 8, 100) == b"89"
    assert lru.get_range("obj", 4, 4) == b""
    assert s3.stats["requests"] == 0   # all hits, base never touched
    assert lru.hits >= 3


def test_lru_range_misses_pass_through_without_filling():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    lru = dl.LRUCacheProvider(s3, capacity_bytes=1 << 10)
    s3.base.put("cold", PAYLOAD)   # only in the base tier
    assert lru.get_range("cold", 0, 4) == b"0123"
    assert lru.misses == 1
    # streaming reads must not fill the cache (no eviction pressure)
    assert lru.get_range("cold", 0, 4) == b"0123"
    assert lru.misses == 2
    assert s3.stats["ranged_requests"] == 2


def test_chain_helper_builds_lru_over_s3():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    chained = dl.chain(dl.MemoryProvider(), s3, capacity_bytes=1 << 10)
    chained.put("obj", PAYLOAD)
    s3.reset_stats()
    assert chained.get_range("obj", 0, 100) == PAYLOAD
    assert s3.stats["requests"] == 0


def test_ranges_match_full_get_suffixes(provider):
    """get_range(k, s, e) == get(k)[s:e] for every boundary combination."""
    full = provider.get("obj")
    for s in (0, 1, 5, 9, 10, 15):
        for e in (0, 1, 5, 10, 11, 100):
            assert provider.get_range("obj", s, e) == full[s:e], (s, e)
