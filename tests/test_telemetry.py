"""End-to-end telemetry (core/telemetry.py): span tracing, unified
metrics registry, IO-cause stall attribution.

Covers the PR-8 contract: nested cause-tagged spans with a shared no-op
disabled path, Chrome trace_event export, the process-wide metrics
registry mirroring commit/waste counters, the provider's per-cause
``sim_s_*`` partition invariant, the fig6 stall decomposition summing
exactly to its total, and traced chaos runs containing the fault-recovery
spans (``fetch.retry``, ``fetch.hedge``, ``commit.rebase``).
"""

import json
import threading
import time

import numpy as np
import pytest

import repro.core as dl
from repro.core import telemetry
from repro.core.fetch import FetchEngine, RetryPolicy
from repro.core.telemetry import (attribute_stall, get_tracer, null_span,
                                  registry, sim_cause_partition, tracing)


# ------------------------------------------------------------ span basics
def test_disabled_path_is_shared_noop():
    """When tracing is off, every span call returns the SAME no-op object
    (no allocation) and nothing is recorded."""
    tr = get_tracer()
    assert not telemetry.enabled()
    tr.clear()
    s1 = telemetry.span("query.plan", x=1)
    s2 = telemetry.gspan(3, "fetch")
    assert s1 is s2 is null_span()
    with s1:
        s1.set(anything=1)  # no-op, chainable
    assert tr.events() == []


def test_span_nesting_parent_depth_and_ordering():
    with tracing() as tr:
        with telemetry.span("query.plan"):
            with telemetry.gspan(0, "fetch", rows=8):
                pass
            with telemetry.gspan(1, "decode"):
                pass
    evs = tr.events()
    # children record at exit, before the parent
    assert [e.name for e in evs] == [
        "scan.group[0].fetch", "scan.group[1].decode", "query.plan"]
    by = {e.name: e for e in evs}
    assert by["query.plan"].depth == 0 and by["query.plan"].parent is None
    for child in ("scan.group[0].fetch", "scan.group[1].decode"):
        assert by[child].depth == 1
        assert by[child].parent == "query.plan"
    assert by["scan.group[0].fetch"].args["rows"] == 8
    # timestamps are epoch-relative and non-negative; durations sane
    assert all(e.ts >= 0 and e.dur >= 0 for e in evs)


def test_span_records_error_arg_on_exception():
    with tracing() as tr:
        with pytest.raises(ValueError):
            with telemetry.span("commit.publish"):
                raise ValueError("boom")
    (ev,) = tr.events()
    assert ev.args["error"] == "ValueError"


def test_report_normalises_group_indices():
    with tracing() as tr:
        for i in range(5):
            with telemetry.gspan(i, "fetch"):
                pass
    rep = tr.report()
    assert rep["scan.group[*].fetch"]["count"] == 5
    assert rep["scan.group[*].fetch"]["total_s"] >= 0.0


def test_chrome_export_shape():
    with tracing() as tr:
        with telemetry.span("query.plan", effective=2):
            pass
    doc = tr.export_chrome()
    evs = doc["traceEvents"]
    assert evs[0] == {"ph": "M", "pid": 1, "name": "process_name",
                      "args": {"name": "repro-lakehouse"}}
    (x,) = [e for e in evs if e["ph"] == "X"]
    assert x["name"] == "query.plan" and x["cat"] == "query"
    assert x["tid"] == threading.get_ident()
    assert x["ts"] >= 0 and x["dur"] >= 0          # microseconds
    assert x["args"]["effective"] == 2 and x["args"]["depth"] == 0
    # round-trips through json
    json.dumps(doc)


def test_write_chrome_artifact(tmp_path):
    path = tmp_path / "trace.json"
    with tracing() as tr:
        with telemetry.span("scan.group[2].deliver", rows=4):
            pass
    tr.write_chrome(str(path))
    doc = json.loads(path.read_text())
    names = [e["name"] for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert names == ["scan.group[2].deliver"]


def test_tracer_thread_safety_and_per_thread_stacks():
    """Spans on different threads keep independent nesting stacks."""
    with tracing() as tr:
        def work(i):
            with telemetry.span(f"outer[{i}].a"):
                with telemetry.span(f"inner[{i}].b"):
                    pass
        ts = [threading.Thread(target=work, args=(i,)) for i in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    evs = tr.events()
    assert len(evs) == 16
    for e in evs:
        if e.name.startswith("inner"):
            i = e.name.split("[")[1].split("]")[0]
            assert e.depth == 1 and e.parent == f"outer[{i}].a"
        else:
            assert e.depth == 0 and e.parent is None


# ------------------------------------------------------------ registry
def test_registry_counter_gauge_histogram_snapshot_delta():
    reg = telemetry.MetricsRegistry()
    reg.counter("commit.rebases").inc()
    reg.counter("commit.rebases").inc(2)
    reg.gauge("loader.inflight").set(7.5)
    h = reg.histogram("fetch.wall_s")
    h.observe(0.25)
    h.observe(0.75)
    snap = reg.snapshot()
    assert snap["commit_rebases"] == 3
    assert snap["loader_inflight"] == 7.5
    assert snap["fetch_wall_s_count"] == 2
    assert snap["fetch_wall_s_sum"] == pytest.approx(1.0)
    assert snap["fetch_wall_s_min"] == 0.25
    assert snap["fetch_wall_s_max"] == 0.75
    reg.counter("commit.rebases").inc(4)
    d = reg.delta(snap)
    assert d["commit_rebases"] == 4
    with pytest.raises(TypeError):
        reg.gauge("commit.rebases")       # name already bound to a Counter


def test_provider_snapshot_merges_engine_stats():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    s3.put("k", b"x" * 100)
    eng = dl.engine_for(s3)
    eng.fetch_full("k")
    snap = telemetry.provider_snapshot(s3)
    assert snap["requests"] >= 1
    assert "sim_s_demand" in snap
    assert "engine_requests" in snap and "engine_retries" in snap
    assert all(isinstance(v, (int, float)) for v in snap.values())


# ------------------------------------------------- sim-cause partition
def test_sim_partition_covers_all_charges_clean_and_faulted():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    s3.put("a", b"x" * 1000)            # write charge
    s3.exists("a")                      # meta charge
    s3.get("a")                         # demand
    with telemetry.io_cause("prefetch"):
        s3.get("a")                     # prefetch
    part = sim_cause_partition(s3.stats)
    assert part["write"] > 0 and part["meta"] > 0
    assert part["demand"] > 0 and part["prefetch"] > 0
    assert sum(part.values()) == pytest.approx(s3.stats["sim_seconds"])

    # injected faults charge their overtime to the fault bucket and the
    # partition stays exhaustive
    s3.fault_policy = dl.FaultPolicy(timeout_rate=1.0, seed=1,
                                     max_consecutive_per_key=2)
    eng = FetchEngine(s3)
    eng.fetch_full("a")
    part = sim_cause_partition(s3.stats)
    assert part["fault"] > 0
    assert part["retry"] > 0            # retried attempts re-tag their IO
    assert sum(part.values()) == pytest.approx(s3.stats["sim_seconds"])


def test_reset_stats_clears_cause_buckets():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    s3.put("a", b"x" * 10)
    s3.reset_stats()
    assert all(v == 0 for v in sim_cause_partition(s3.stats).values())
    assert s3.stats["sim_seconds"] == 0


# ------------------------------------------------- stall attribution
def test_attribute_stall_priority_and_exact_total():
    out = attribute_stall({"demand": 8.0, "retry": 1.0}, compute_s=0.5)
    # pure overhead (retry) absorbs stall first, then demand fetch
    assert out["retry_hedge_s"] == pytest.approx(1.0)
    assert out["demand_fetch_s"] == pytest.approx(7.5)
    assert out["unattributed_s"] == pytest.approx(0.0)
    assert out["total_s"] == pytest.approx(8.5)
    causes = sum(v for k, v in out.items() if k != "total_s")
    assert causes == pytest.approx(out["total_s"])


def test_attribute_stall_no_stall_and_parallelism():
    # IO fully hidden by compute -> zero everywhere
    out = attribute_stall({"demand": 1.0}, compute_s=5.0, parallelism=8)
    assert out["total_s"] == 0.0
    assert all(v == 0.0 for v in out.values())
    # parallelism divides the raw sim seconds; decode folds in
    out = attribute_stall({"demand": 8.0}, compute_s=0.0, parallelism=8,
                          decode_s=0.5)
    assert out["total_s"] == pytest.approx(1.5)
    assert out["demand_fetch_s"] == pytest.approx(1.0)
    assert out["decode_s"] == pytest.approx(0.5)


def test_attribute_stall_unknown_cause_lands_unattributed():
    out = attribute_stall({"mystery": 2.0}, compute_s=0.0)
    assert out["unattributed_s"] == pytest.approx(2.0)
    assert out["total_s"] == pytest.approx(2.0)


# ------------------------------------------------- traced fault recovery
def test_traced_chaos_run_contains_retry_spans():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    s3.put("k", b"y" * 500)
    s3.fault_policy = dl.FaultPolicy(timeout_rate=1.0, seed=1,
                                     max_consecutive_per_key=2)
    eng = FetchEngine(s3)
    with tracing() as tr:
        blob = eng.fetch_full("k")
    assert blob == b"y" * 500
    retries = tr.find("fetch.retry")
    assert retries, "faulted fetch recorded no fetch.retry spans"
    assert retries[0].args["key"] == "k"
    assert retries[0].args["attempt"] >= 1


def test_traced_straggler_produces_hedge_span():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    for i in range(10):
        s3.put(f"b{i}", b"z" * 100)
    s3.put("slow", b"z" * 100)
    eng = FetchEngine(s3, retry=RetryPolicy(hedge_min_s=0.05))
    for i in range(10):                 # establish the clean-wall baseline
        eng.fetch_full(f"b{i}")
    assert eng.detector.baseline is not None
    # every read now straggles (real 0.2s sleep) far past the 50ms floor
    s3.fault_policy = dl.FaultPolicy(straggle_rate=1.0, straggle_sleep_s=0.2,
                                     seed=3, max_consecutive_per_key=2)
    with tracing() as tr:
        blob = eng.fetch_full("slow")
    assert blob == b"z" * 100
    assert eng.stats_snapshot()["hedges"] >= 1
    hedges = tr.find("fetch.hedge")
    assert hedges, "straggling fetch recorded no fetch.hedge span"
    assert hedges[0].args["key"] == "slow"


def test_traced_contended_commit_produces_rebase_span_and_counters():
    store = dl.MemoryProvider()
    ds0 = dl.Dataset(store)
    for t in ("a", "b"):
        ds0.create_tensor(t, dtype="float32", min_chunk_size=1 << 11,
                          max_chunk_size=1 << 12)
    ds0.commit("init")
    wa, wb = dl.Dataset(store), dl.Dataset(store)
    for i in range(4):
        wa["a"].append(np.full(16, i, np.float32))
        wb["b"].append(np.full(16, 100 + i, np.float32))
    reg0 = registry().snapshot()
    with tracing() as tr:
        wa.commit("writer a")
        wb.commit("writer b")           # loses the CAS race -> rebase
    rebases = tr.find("commit.rebase")
    assert rebases, "contended commit recorded no commit.rebase span"
    assert rebases[0].args["shape"] in ("adopt", "relocate")
    assert tr.count("commit.publish") >= 2
    regd = registry().delta(reg0)
    assert regd["commit_commits"] == 2
    assert regd["commit_rebases"] == wb.vc.commit_stats["rebases"] >= 1
    assert regd.get("commit_relocations", 0) == \
        wb.vc.commit_stats["relocations"]
    assert regd.get("commit_adoptions", 0) == wb.vc.commit_stats["adoptions"]


# ------------------------------------------------- loader + pipeline spans
def _image_ds(n=96):
    ds = dl.Dataset(dl.MemoryProvider())
    ds.create_tensor("images", htype="image", dtype="uint8",
                     sample_compression="zlib", min_chunk_size=16 << 10,
                     max_chunk_size=32 << 10)
    ds.create_tensor("labels", htype="class_label")
    rng = np.random.default_rng(5)
    for i in range(n):
        ds.append({"images": rng.integers(0, 255, (24, 24, 3), np.uint8),
                   "labels": np.int64(i)})
    ds.commit("data")
    return ds


def test_traced_loader_emits_scan_spans_and_stall_causes_sum():
    ds = _image_ds()
    loader = ds.dataloader(batch_size=16, shuffle=False, num_workers=2,
                           seed=0)
    with tracing() as tr:
        n = sum(len(b["labels"]) for b in loader)
    assert n == 96
    assert tr.count("scan.group") > 0          # fetch/decode worker spans
    rep = tr.report()
    assert any(k.startswith("scan.group[*]") for k in rep)
    st = loader.stats
    assert st.wait_seconds == pytest.approx(
        sum(st.stall_by_cause.values())), \
        "stall_by_cause must partition wait_seconds exactly"
    assert set(st.stall_by_cause) <= {"fetch", "decode", "buffer_full"}
    stalls = tr.find("loader.stall")
    for e in stalls:
        assert e.args["cause"] in ("fetch", "decode", "buffer_full")


def test_disabled_tracing_adds_no_events_on_hot_paths():
    """The whole read pipeline under disabled tracing must leave the
    global tracer buffer empty — no span leaks from the wired call sites."""
    tr = get_tracer()
    tr.clear()
    assert not telemetry.enabled()
    ds = _image_ds(n=48)
    loader = ds.dataloader(batch_size=16, shuffle=False, num_workers=2,
                           seed=0)
    assert sum(len(b["labels"]) for b in loader) == 48
    assert tr.events() == []


def test_tql_query_spans():
    ds = _image_ds(n=64)
    with tracing() as tr:
        view = ds.query("SELECT * FROM dataset WHERE labels < 10",
                        engine="numpy")
        assert len(view.indices) == 10
    assert tr.count("query.plan") == 1
    assert tr.count("query.where") == 1
