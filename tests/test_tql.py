"""TQL (C3): parser, executor, engine equivalence, paper Fig-4 query."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as dl
from repro.core.tql import TQLSyntaxError, execute_query, parse
from repro.core.tql.functions import iou, normalize_boxes


@pytest.fixture(scope="module")
def ds():
    rng = np.random.default_rng(7)
    ds = dl.dataset()
    ds.create_tensor("images", htype="image", dtype="uint8",
                     sample_compression="raw", min_chunk_size=1 << 14,
                     max_chunk_size=1 << 16)
    ds.create_tensor("labels", htype="class_label")
    ds.create_tensor("boxes", htype="bbox", strict=False)
    ds.group("training").create_tensor("boxes", htype="bbox", strict=False)
    ds.create_tensor("caption", htype="text")
    words = ["cat", "dog", "car", "sky"]
    for i in range(40):
        gt = rng.uniform(0, 24, (2, 4)).astype(np.float32)
        gt[:, 2:] += gt[:, :2]
        ds.append({
            "images": rng.integers(0, 255, (32, 32, 3), dtype=np.uint8),
            "labels": np.int64(i % 4),
            "boxes": (gt + rng.normal(0, 1.0, gt.shape)).astype(np.float32),
            "training/boxes": gt,
            "caption": np.frombuffer(f"a {words[i % 4]} photo".encode(),
                                     dtype=np.uint8).copy(),
        })
    ds.commit("fixture")
    return ds


# ----------------------------------------------------------------- parsing
def test_parse_structure():
    q = parse("SELECT a, MEAN(b) AS mb FROM dataset WHERE a > 1 "
              "ORDER BY mb DESC LIMIT 7 OFFSET 2")
    assert q.limit == 7 and q.offset == 2 and q.order_desc
    assert set(q.referenced_tensors()) >= {"a", "b"}
    # alias in ORDER BY resolves to its SELECT expression
    rng = np.random.default_rng(0)
    ds = dl.dataset()
    ds.create_tensor("a", dtype="float32")
    ds.create_tensor("b", dtype="float32")
    for i in range(6):
        ds.append({"a": np.float32(i), "b": rng.standard_normal(4).astype(np.float32)})
    v = ds.query("SELECT a, MEAN(b) AS mb FROM dataset WHERE a > 1 "
                 "ORDER BY mb DESC LIMIT 3")
    ms = [float(np.mean(r["mb"])) for r in v.rows()]
    assert ms == sorted(ms, reverse=True)


def test_parse_errors():
    for bad in ("SELECT", "SELECT * FROM", "SELECT * WHERE x ^ 2",
                "SELECT a FROM ds LIMIT x"):
        with pytest.raises(TQLSyntaxError):
            parse(bad)


def test_parse_slicing_and_lists():
    q = parse("SELECT x[1:5, :, 2] AS crop, [1, 2, 3] AS lst FROM ds")
    assert q.items[0].alias == "crop"


# ----------------------------------------------------------------- executor
def test_where_oracle_equivalence(ds):
    v = ds.query("SELECT * FROM dataset WHERE labels == 2 AND MEAN(images) > 100")
    want = [i for i in range(40)
            if int(ds.labels[i]) == 2 and float(ds.images[i].mean()) > 100]
    assert v.indices.tolist() == want


def test_order_by_matches_numpy(ds):
    v = ds.query("SELECT * FROM dataset ORDER BY MEAN(images) DESC")
    means = np.array([float(ds.images[i].mean()) for i in range(40)])
    want = np.argsort(-means, kind="stable")
    assert v.indices.tolist() == want.tolist()


def test_paper_fig4_query(ds):
    v = ds.query('''
        SELECT images[8:24, 8:24, 0:2] AS crop,
               NORMALIZE(boxes, [8, 8, 24, 24]) AS box
        FROM dataset
        WHERE IOU(boxes, "training/boxes") > 0.3
        ORDER BY IOU(boxes, "training/boxes")
        ARRANGE BY labels''')
    assert len(v) > 0
    r = v.row(0)
    assert r["crop"].shape == (16, 16, 2)
    assert r["box"].min() >= 0.0 and r["box"].max() <= 1.0
    labs = [int(ds.labels[int(i)]) for i in v.indices]
    assert labs == sorted(labs)


def test_engines_agree(ds):
    q = "SELECT * FROM dataset WHERE MEAN(images) > 120 AND NOT labels == 1"
    a = execute_query(ds, q, engine="numpy")
    b = execute_query(ds, q, engine="jax")
    c = execute_query(ds, q, engine="auto")
    assert a.indices.tolist() == b.indices.tolist() == c.indices.tolist()


def test_contains_on_text(ds):
    v = ds.query('SELECT * FROM dataset WHERE CONTAINS(caption, "dog")')
    assert len(v) == 10
    assert all(int(ds.labels[int(i)]) == 1 for i in v.indices)


def test_sample_by_weights_and_determinism(ds):
    q = "SELECT * FROM dataset SAMPLE BY labels * labels LIMIT 200"
    a, b = ds.query(q), ds.query(q)
    assert a.indices.tolist() == b.indices.tolist()
    labs = np.array([int(ds.labels[int(i)]) for i in a.indices])
    assert (labs == 3).sum() > (labs == 1).sum()
    assert (labs == 0).sum() == 0   # zero weight never sampled


def test_shape_function_and_arithmetic(ds):
    v = ds.query("SELECT * FROM dataset WHERE SHAPE(images)[0] == 32 LIMIT 3")
    assert len(v) == 3
    v2 = ds.query("SELECT MEAN(images) / 255.0 AS m FROM dataset LIMIT 4")
    for r in v2.rows():
        assert 0 <= float(r["m"]) <= 1


def test_random_deterministic(ds):
    q = "SELECT * FROM dataset WHERE RANDOM() < 0.5"
    assert ds.query(q).indices.tolist() == ds.query(q).indices.tolist()


def test_query_chaining_and_loader_handoff(ds):
    v = ds.query("SELECT * FROM dataset WHERE labels == 0")
    v2 = v.query("SELECT images FROM view ORDER BY MEAN(images) LIMIT 4")
    loader = v2.dataloader(batch_size=2, tensors=["images"], num_workers=2)
    batches = list(loader)
    assert sum(len(b["images"]) for b in batches) == 4


# ---------------------------------------------------------------- functions
def test_iou_identity_and_disjoint():
    a = np.array([[0, 0, 10, 10]], np.float32)
    assert iou(a, a) == pytest.approx(1.0)
    b = np.array([[20, 20, 30, 30]], np.float32)
    assert iou(a, b) == 0.0


def test_normalize_boxes_bounds():
    out = normalize_boxes(np.array([[5, 5, 15, 15]], np.float32),
                          [0, 0, 20, 20])
    np.testing.assert_allclose(out, [[0.25, 0.25, 0.75, 0.75]])


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 3), st.floats(0, 254.0))
def test_generated_where_matches_oracle(label, thresh):
    """Property: executor == numpy oracle for a family of queries."""
    rng = np.random.default_rng(11)
    ds = dl.dataset()
    ds.create_tensor("v", dtype="float32")
    ds.create_tensor("lab", htype="class_label")
    vals = rng.uniform(0, 255, (25, 4)).astype(np.float32)
    for i in range(25):
        ds.append({"v": vals[i], "lab": np.int64(i % 4)})
    q = f"SELECT * FROM dataset WHERE lab == {label} OR MEAN(v) > {thresh}"
    got = ds.query(q).indices.tolist()
    want = [i for i in range(25)
            if (i % 4 == label) or (vals[i].mean() > thresh)]
    assert got == want
