"""GROUP BY / aggregate TQL queries: value parity against a numpy/dict
reference, the stats-only fast path (zero payload fetches), streaming-fold
memory bounds, and the parser's aggregation-shape validation.

Every aggregation query must return identical values across use_stats
on/off and stream on/off/auto (COUNT/MIN/MAX exactly; SUM/AVG to float64
tolerance — accumulation order differs between the per-chunk partial folds
and a whole-view fold), over clustered ints, NaN columns, ragged tensors
with empty samples, text keys, and v1/v2/v3 manifest formats.
"""

import json
import math

import numpy as np
import pytest

import repro.core as dl
from repro.core.manifest import MANIFEST_KEY
from repro.core.pipeline import ScanPipeline
from repro.core.tql import TQLSyntaxError, execute_query, parse
from repro.core.tql.executor import Executor
from repro.core.tql.functions import get_function
from repro.core.views import DatasetView


def _build(storage=None, n=240):
    """Clustered dataset: 8 bands of 30 rows, every tensor chunked small so
    one query spans many chunk groups (the streaming fold has granularity)."""
    rng = np.random.default_rng(17)
    ds = dl.Dataset(storage)
    ds.create_tensor("val", dtype="float32", min_chunk_size=512,
                     max_chunk_size=1024)
    ds.create_tensor("lab", htype="class_label", min_chunk_size=128,
                     max_chunk_size=256)
    ds.create_tensor("m3", dtype="int64", min_chunk_size=128,
                     max_chunk_size=256)
    ds.create_tensor("nanny", dtype="float32", min_chunk_size=128,
                     max_chunk_size=256)
    ds.create_tensor("rag", dtype="float32", strict=False,
                     min_chunk_size=256, max_chunk_size=512)
    ds.create_tensor("txt", htype="text")
    rows = []
    for i in range(n):
        band = i // 30
        nanny = np.float32(np.nan) if i % 7 == 0 else np.float32(band + 0.5)
        row = {
            "val": (rng.standard_normal(8).astype(np.float32)
                    + np.float32(band * 10)),
            "lab": np.int64(band),
            "m3": np.int64(i % 3),
            "nanny": np.asarray([nanny], np.float32),
            "rag": rng.uniform(1, 2, (i % 5,)).astype(np.float32),
            "txt": np.frombuffer(f"band {band}".encode(),
                                 dtype=np.uint8).copy(),
        }
        ds.append(row)
        rows.append(row)
    ds.commit("agg fixture")
    return ds, rows


@pytest.fixture(scope="module")
def fixture():
    return _build()


def _ref_groups(rows, keyf):
    """Group row dicts by key in first-appearance order."""
    groups, order = {}, []
    for r in rows:
        k = keyf(r)
        if k not in groups:
            groups[k] = []
            order.append(k)
        groups[k].append(r)
    return order, groups


def _ref_agg(samples, func):
    """Reference aggregate over all elements of a group's samples,
    NaN-skipping, with the executor's empty identities."""
    flat = (np.concatenate([np.asarray(s, np.float64).ravel()
                            for s in samples])
            if samples else np.empty(0))
    valid = flat[~np.isnan(flat)]
    if func == "COUNT":
        return len(samples)
    if func == "SUM":
        return float(valid.sum()) if valid.size else 0
    if not valid.size:
        return float("nan")
    return {"MIN": valid.min, "MAX": valid.max, "AVG": valid.mean}[func]()


def _assert_close(got, want, exact):
    if isinstance(want, float) and math.isnan(want):
        assert math.isnan(float(got))
    elif exact:
        assert got == want
    else:
        assert np.isclose(float(got), float(want), rtol=1e-6, atol=1e-9)


MODES = [(True, None), (True, True), (True, False),
         (False, None), (False, True), (False, False)]


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("use_stats,stream", MODES)
def test_grouped_aggregates_match_reference(fixture, use_stats, stream):
    ds, rows = fixture
    v = execute_query(
        ds, "SELECT lab, COUNT() AS c, SUM(val) AS s, MIN(val) AS mn, "
        "MAX(val) AS mx, AVG(val) AS av FROM dataset GROUP BY lab",
        use_stats=use_stats, stream=stream)
    order, groups = _ref_groups(rows, lambda r: int(r["lab"]))
    assert [int(k) for k in v.derived["lab"]] == order
    for j, k in enumerate(order):
        samples = [r["val"] for r in groups[k]]
        for col, func, exact in (("c", "COUNT", True), ("s", "SUM", False),
                                 ("mn", "MIN", True), ("mx", "MAX", True),
                                 ("av", "AVG", False)):
            _assert_close(v.derived[col][j], _ref_agg(samples, func), exact)


@pytest.mark.parametrize("use_stats,stream", MODES)
def test_ungrouped_aggregates_match_reference(fixture, use_stats, stream):
    ds, rows = fixture
    v = execute_query(
        ds, "SELECT COUNT() AS c, SUM(val) AS s, MIN(val) AS mn, "
        "MAX(val) AS mx, AVG(val) AS av FROM dataset",
        use_stats=use_stats, stream=stream)
    assert len(v) == 1
    samples = [r["val"] for r in rows]
    for col, func, exact in (("c", "COUNT", True), ("s", "SUM", False),
                             ("mn", "MIN", True), ("mx", "MAX", True),
                             ("av", "AVG", False)):
        _assert_close(v.derived[col][0], _ref_agg(samples, func), exact)


@pytest.mark.parametrize("use_stats", [True, False])
def test_nan_values_skipped_and_nan_keys_share_a_group(fixture, use_stats):
    ds, rows = fixture
    # NaN *values* are skipped by SUM/MIN/MAX/AVG (stats accumulate the
    # same way), but COUNT still counts the rows
    v = execute_query(
        ds, "SELECT lab, COUNT() AS c, SUM(nanny) AS s, AVG(nanny) AS av "
        "FROM dataset GROUP BY lab", use_stats=use_stats)
    order, groups = _ref_groups(rows, lambda r: int(r["lab"]))
    for j, k in enumerate(order):
        samples = [r["nanny"] for r in groups[k]]
        _assert_close(v.derived["c"][j], _ref_agg(samples, "COUNT"), True)
        _assert_close(v.derived["s"][j], _ref_agg(samples, "SUM"), False)
        _assert_close(v.derived["av"][j], _ref_agg(samples, "AVG"), False)
    # NaN *keys* land in one shared group (NaN != NaN must not split it)
    vk = execute_query(ds, "SELECT nanny, COUNT() AS c FROM dataset "
                       "GROUP BY nanny", use_stats=use_stats)
    nan_rows = [j for j, k in enumerate(vk.derived["nanny"])
                if math.isnan(float(k))]
    assert len(nan_rows) == 1
    want = sum(1 for r in rows if math.isnan(float(r["nanny"][0])))
    assert vk.derived["c"][nan_rows[0]] == want


@pytest.mark.parametrize("use_stats", [True, False])
def test_ragged_and_empty_samples(fixture, use_stats):
    ds, rows = fixture
    v = execute_query(
        ds, "SELECT m3, COUNT() AS c, SUM(rag) AS s, MIN(rag) AS mn, "
        "AVG(rag) AS av FROM dataset GROUP BY m3", use_stats=use_stats)
    order, groups = _ref_groups(rows, lambda r: int(r["m3"]))
    assert [int(k) for k in v.derived["m3"]] == order
    for j, k in enumerate(order):
        samples = [r["rag"] for r in groups[k]]
        _assert_close(v.derived["c"][j], _ref_agg(samples, "COUNT"), True)
        _assert_close(v.derived["s"][j], _ref_agg(samples, "SUM"), False)
        _assert_close(v.derived["mn"][j], _ref_agg(samples, "MIN"), True)
        _assert_close(v.derived["av"][j], _ref_agg(samples, "AVG"), False)


def test_all_empty_group_yields_identities():
    ds = dl.Dataset()
    ds.create_tensor("k", dtype="int64")
    ds.create_tensor("r", dtype="float32", strict=False)
    for i in range(20):
        # group 1's samples are ALL empty: SUM 0, MIN/MAX/AVG NaN
        ds.append({"k": np.int64(i % 2),
                   "r": (np.empty(0, np.float32) if i % 2 else
                         np.full(3, 2.0, np.float32))})
    ds.commit("c")
    v = execute_query(ds, "SELECT k, COUNT() AS c, SUM(r) AS s, "
                      "MIN(r) AS mn, AVG(r) AS av FROM dataset GROUP BY k")
    assert [int(k) for k in v.derived["k"]] == [0, 1]
    assert v.derived["c"] == [10, 10]
    assert v.derived["s"][1] == 0
    assert math.isnan(v.derived["mn"][1])
    assert math.isnan(v.derived["av"][1])


def test_text_and_expression_and_composite_keys(fixture):
    ds, rows = fixture
    # text-htype key: uint8 samples decode to strings
    v = execute_query(ds, "SELECT txt, COUNT() AS c FROM dataset GROUP BY txt")
    order, groups = _ref_groups(rows, lambda r: r["txt"].tobytes().decode())
    assert list(v.derived["txt"]) == order
    assert v.derived["c"] == [len(groups[k]) for k in order]
    # expression key, matched structurally by the SELECT item
    v = execute_query(ds, "SELECT lab % 2 AS par, COUNT() AS c "
                      "FROM dataset GROUP BY lab % 2")
    order, groups = _ref_groups(rows, lambda r: int(r["lab"]) % 2)
    assert [int(k) for k in v.derived["par"]] == order
    assert v.derived["c"] == [len(groups[k]) for k in order]
    # composite key
    v = execute_query(ds, "SELECT lab, m3, COUNT() AS c FROM dataset "
                      "GROUP BY lab, m3")
    order, groups = _ref_groups(rows, lambda r: (int(r["lab"]), int(r["m3"])))
    got = list(zip((int(k) for k in v.derived["lab"]),
                   (int(k) for k in v.derived["m3"])))
    assert got == order
    assert v.derived["c"] == [len(groups[k]) for k in order]


@pytest.mark.parametrize("use_stats", [True, False])
def test_where_then_group_by(fixture, use_stats):
    ds, rows = fixture
    v = execute_query(ds, "SELECT lab, COUNT() AS c, MAX(val) AS mx "
                      "FROM dataset WHERE lab >= 3 AND m3 != 0 GROUP BY lab",
                      use_stats=use_stats)
    kept = [r for r in rows if int(r["lab"]) >= 3 and int(r["m3"]) != 0]
    order, groups = _ref_groups(kept, lambda r: int(r["lab"]))
    assert [int(k) for k in v.derived["lab"]] == order
    for j, k in enumerate(order):
        assert v.derived["c"][j] == len(groups[k])
        _assert_close(v.derived["mx"][j],
                      _ref_agg([r["val"] for r in groups[k]], "MAX"), True)


def test_limit_offset_slice_group_rows(fixture):
    ds, rows = fixture
    full = execute_query(ds, "SELECT lab, COUNT() AS c FROM dataset "
                         "GROUP BY lab")
    v = execute_query(ds, "SELECT lab, COUNT() AS c FROM dataset "
                      "GROUP BY lab LIMIT 3 OFFSET 2")
    assert list(v.derived["lab"]) == list(full.derived["lab"])[2:5]
    assert list(v.derived["c"]) == list(full.derived["c"])[2:5]


def test_view_order_and_duplicate_rows(fixture):
    ds, rows = fixture
    rng = np.random.default_rng(4)
    perm = rng.permutation(len(rows))
    view = DatasetView.full(ds)[perm]
    v = execute_query(view, "SELECT lab, COUNT() AS c FROM view GROUP BY lab")
    order, groups = _ref_groups([rows[i] for i in perm],
                                lambda r: int(r["lab"]))
    assert [int(k) for k in v.derived["lab"]] == order
    assert v.derived["c"] == [len(groups[k]) for k in order]
    # duplicated rows: stats path must stand down (full-coverage gate) and
    # COUNT must count every occurrence
    dup = DatasetView.full(ds)[np.asarray([0, 0, 1, 31, 31, 31])]
    vd = execute_query(dup, "SELECT lab, COUNT() AS c FROM view GROUP BY lab")
    assert [int(k) for k in vd.derived["lab"]] == [0, 1]
    assert vd.derived["c"] == [3, 3]
    assert vd.scan_plan["agg_groups_stats_answered"] == 0


def test_empty_view_identity_row_and_empty_groups(fixture):
    ds, _rows = fixture
    v = execute_query(ds, "SELECT COUNT() AS c, SUM(val) AS s, MIN(val) AS mn "
                      "FROM dataset WHERE lab > 1000")
    assert len(v) == 1
    assert v.derived["c"] == [0] and v.derived["s"] == [0]
    assert math.isnan(v.derived["mn"][0])
    vg = execute_query(ds, "SELECT lab, COUNT() AS c FROM dataset "
                       "WHERE lab > 1000 GROUP BY lab")
    assert len(vg) == 0 and vg.derived["c"] == []


def test_int_sum_is_exact_above_float53(monkeypatch):
    """Integer SUM accumulates as Python int: values whose float64 sum
    would round stay exact, on both the fold and the stats paths."""
    big = 2 ** 53
    ds = dl.Dataset()
    ds.create_tensor("b", dtype="int64", min_chunk_size=256,
                     max_chunk_size=512)
    for _ in range(40):
        ds.append({"b": np.asarray([big, 1], np.int64)})
    ds.commit("c")
    want = 40 * (big + 1)
    for use_stats in (True, False):
        v = execute_query(ds, "SELECT SUM(b) AS s, COUNT() AS c FROM dataset",
                          use_stats=use_stats)
        assert v.derived["s"][0] == want       # float64 would give 40*big
        assert v.derived["c"][0] == 40
    # ...and MIN/MAX beyond 2**53 refuse the stats answer (widened bounds)
    v = execute_query(ds, "SELECT MIN(b) AS mn, MAX(b) AS mx FROM dataset")
    assert v.derived["mn"][0] == float(1)
    assert v.derived["mx"][0] == float(big)


# --------------------------------------------------------- stats fast path
def test_ungrouped_aggregate_is_answered_with_zero_requests():
    base = dl.MemoryProvider()
    _ds, rows = _build(base)
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    cold = dl.Dataset(s3)
    open_requests = s3.stats["requests"]
    v = execute_query(cold, "SELECT COUNT() AS c, SUM(val) AS s, "
                      "MIN(val) AS mn, MAX(val) AS mx, AVG(val) AS av "
                      "FROM dataset")
    assert s3.stats["requests"] == open_requests, \
        "stats-only aggregate fetched payloads"
    plan = v.scan_plan
    assert plan["agg_groups"] > 0
    assert plan["agg_groups_stats_answered"] == plan["agg_groups"]
    assert plan["agg_groups_folded"] == 0
    samples = [r["val"] for r in rows]
    for col, func, exact in (("c", "COUNT", True), ("s", "SUM", False),
                             ("mn", "MIN", True), ("mx", "MAX", True),
                             ("av", "AVG", False)):
        _assert_close(v.derived[col][0], _ref_agg(samples, func), exact)


def test_grouped_single_valued_key_chunks_answer_from_sketch():
    """A constant-label dataset: every key chunk's dictionary sketch has
    exactly one entry, so the whole grouped aggregate is stats-answered
    with zero payload fetches."""
    base = dl.MemoryProvider()
    ds = dl.Dataset(base)
    ds.create_tensor("lab", htype="class_label", min_chunk_size=128,
                     max_chunk_size=256)
    for _ in range(200):
        ds.append({"lab": np.int64(5)})
    ds.commit("c")
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    cold = dl.Dataset(s3)
    open_requests = s3.stats["requests"]
    v = execute_query(cold, "SELECT lab, COUNT() AS c, SUM(lab) AS s, "
                      "AVG(lab) AS av FROM dataset GROUP BY lab")
    assert s3.stats["requests"] == open_requests
    plan = v.scan_plan
    assert plan["agg_groups"] > 1
    assert plan["agg_groups_stats_answered"] == plan["agg_groups"]
    assert [int(k) for k in v.derived["lab"]] == [5]
    assert v.derived["c"] == [200]
    assert v.derived["s"][0] == 200 * 5
    assert v.derived["av"][0] == 5.0


def test_multi_band_grouped_mixes_stats_and_fold(fixture):
    """Band-clustered labels: interior chunks are single-valued (stats-
    answered), band-boundary chunks fold — values stay identical to the
    all-fold run."""
    ds, rows = fixture
    on = execute_query(ds, "SELECT lab, COUNT() AS c, SUM(lab) AS s "
                       "FROM dataset GROUP BY lab", use_stats=True)
    off = execute_query(ds, "SELECT lab, COUNT() AS c, SUM(lab) AS s "
                        "FROM dataset GROUP BY lab", use_stats=False)
    assert on.scan_plan["agg_groups_stats_answered"] > 0
    assert list(on.derived["lab"]) == list(off.derived["lab"])
    assert on.derived["c"] == off.derived["c"]
    assert on.derived["s"] == off.derived["s"]


def test_aggregate_plan_reaches_dataloader_stats(fixture):
    ds, _rows = fixture
    v = execute_query(ds, "SELECT lab, COUNT() AS c FROM dataset "
                      "WHERE lab >= 0 GROUP BY lab")
    assert v.scan_plan["agg_groups_stats_answered"] >= 0
    assert "rows" in v.scan_plan  # WHERE plan and agg plan share the report


# ------------------------------------------------------- streaming memory
def test_streaming_fold_holds_one_chunk_group_at_a_time(fixture, monkeypatch):
    ds, rows = fixture
    view = DatasetView.full(ds)
    pipe = ScanPipeline.for_query(view, ["lab", "val"])
    sizes = [len(pipe.group_positions(g)) for g in range(pipe.n_groups)]
    pipe.close()
    assert max(sizes) < len(rows)
    seen = []
    orig = Executor._agg_fold

    def spy(self, sub, positions, *a, **k):
        seen.append(len(sub))
        return orig(self, sub, positions, *a, **k)

    monkeypatch.setattr(Executor, "_agg_fold", spy)
    v = execute_query(ds, "SELECT lab, COUNT() AS c, SUM(val) AS s "
                      "FROM dataset GROUP BY lab",
                      use_stats=False, stream=True)
    assert len(v) == 8
    assert len(seen) > 1, "fold did not stream per chunk group"
    assert max(seen) <= max(sizes), \
        f"fold held {max(seen)} rows resident; largest group is {max(sizes)}"


# ------------------------------------------------- manifest compatibility
def _strip_stats_fields(base, fields, marker=None, drop_stats=False):
    """Rewrite the persisted manifest (and loose sidecars) without the
    given per-chunk stats fields — simulates records written before the
    field existed (e.g. v2 manifests predate ``sum``)."""
    ptr = json.loads(base.get(MANIFEST_KEY).decode())
    if marker:
        ptr["format"] = marker
    for seg_key in ptr["segments"]:
        seg = json.loads(base.get(seg_key).decode())
        if marker:
            seg["format"] = marker
        for node in seg["nodes"].values():
            if drop_stats:
                node.pop("stats", None)
                continue
            for cs in node.get("stats", {}).values():
                for rec in cs.get("chunks", []):
                    if rec:
                        for f in fields:
                            rec.pop(f, None)
        base.put(seg_key, json.dumps(seg).encode())
    base.put(MANIFEST_KEY, json.dumps(ptr).encode())
    for key in list(base.list_keys()):
        if key.endswith("chunk_stats.json"):
            doc = json.loads(base.get(key).decode())
            for rec in doc.get("chunks", {}).values():
                for f in fields:
                    rec.pop(f, None)
            base.put(key, json.dumps(doc).encode())


def test_v2_manifest_without_sum_field_folds_but_stays_correct():
    base = dl.MemoryProvider()
    _ds, rows = _build(base, n=120)
    _strip_stats_fields(base, ("sum",), marker="deeplake-repro-manifest-v2")
    ds2 = dl.Dataset(base)
    v = execute_query(ds2, "SELECT COUNT() AS c, SUM(val) AS s, "
                      "MIN(val) AS mn FROM dataset")
    samples = [r["val"] for r in rows]
    _assert_close(v.derived["c"][0], _ref_agg(samples, "COUNT"), True)
    _assert_close(v.derived["s"][0], _ref_agg(samples, "SUM"), False)
    _assert_close(v.derived["mn"][0], _ref_agg(samples, "MIN"), True)
    # SUM needs the missing field: every group folds...
    assert v.scan_plan["agg_groups_stats_answered"] == 0
    # ...but a sum-free aggregate still answers from the v2 bounds
    v2 = execute_query(ds2, "SELECT COUNT() AS c, MIN(val) AS mn, "
                       "MAX(val) AS mx FROM dataset")
    assert v2.scan_plan["agg_groups_stats_answered"] \
        == v2.scan_plan["agg_groups"] > 0


def test_v1_manifest_without_stats_still_aggregates():
    base = dl.MemoryProvider()
    _ds, rows = _build(base, n=120)
    _strip_stats_fields(base, ("sum",), marker="deeplake-repro-manifest-v1",
                        drop_stats=True)
    ds2 = dl.Dataset(base)
    v = execute_query(ds2, "SELECT lab, COUNT() AS c, AVG(val) AS av "
                      "FROM dataset GROUP BY lab")
    order, groups = _ref_groups(rows, lambda r: int(r["lab"]))
    assert [int(k) for k in v.derived["lab"]] == order
    for j, k in enumerate(order):
        assert v.derived["c"][j] == len(groups[k])
        _assert_close(v.derived["av"][j],
                      _ref_agg([r["val"] for r in groups[k]], "AVG"), False)


# ------------------------------------------------------------------ parser
@pytest.mark.parametrize("q", [
    "SELECT * FROM ds LIMIT 3.7",
    "SELECT * FROM ds LIMIT -1",
    "SELECT * FROM ds LIMIT 5 OFFSET 1.5",
    "SELECT * FROM ds LIMIT 5 OFFSET -2",
    "SELECT * FROM ds WHERE x > 0 WHERE x < 5",
    "SELECT * FROM ds LIMIT 5 LIMIT 6",
    "SELECT lab, COUNT() FROM ds GROUP BY lab GROUP BY lab",
    "SELECT lab, COUNT() FROM ds GROUP BY lab ARRANGE BY lab",
    "SELECT lab, COUNT() FROM ds GROUP BY lab ORDER BY lab",
    "SELECT lab, COUNT() FROM ds GROUP BY lab SAMPLE BY lab",
    "SELECT lab, COUNT(x) FROM ds GROUP BY lab",
    "SELECT lab, SUM() FROM ds GROUP BY lab",
    "SELECT lab, SUM(x, y) FROM ds GROUP BY lab",
    "SELECT x FROM ds GROUP BY lab",
    "SELECT * FROM ds GROUP BY lab",
    "SELECT COUNT(), x FROM ds",
])
def test_parser_rejects_malformed_queries(q):
    with pytest.raises(TQLSyntaxError):
        parse(q)


def test_parser_accepts_and_shapes_aggregates():
    q = parse("SELECT lab, COUNT() AS c, AVG(val) AS av FROM ds "
              "GROUP BY lab LIMIT 4 OFFSET 1")
    assert q.is_aggregate and q.limit == 4 and q.offset == 1
    assert len(q.group_by) == 1
    q2 = parse("SELECT COUNT() FROM ds")
    assert q2.is_aggregate
    # mixed per-row select without COUNT() stays legacy (MEAN/SUM keep
    # their per-row element-reduction meaning outside aggregation)
    q3 = parse("SELECT MEAN(x) AS m, lab FROM ds LIMIT 3")
    assert not q3.is_aggregate


def test_legacy_per_row_reductions_untouched(fixture):
    ds, rows = fixture
    v = execute_query(ds, "SELECT MIN(rag) AS mn, SUM(rag) AS s, lab "
                      "FROM dataset LIMIT 10")
    assert len(v) == 10
    for j in range(10):
        r = rows[j]["rag"]
        if r.size:
            assert np.isclose(float(v.derived["mn"][j]), float(r.min()))
            assert np.isclose(float(v.derived["s"][j]), float(r.sum()),
                              rtol=1e-6)
        else:  # empty sample: MIN is NaN, SUM is 0 (not 0.0-for-MIN)
            assert math.isnan(float(v.derived["mn"][j]))
            assert float(v.derived["s"][j]) == 0.0


def test_reduce_all_empty_identities_row_and_batched_agree():
    for name in ("MIN", "MAX", "MEAN", "STD"):
        spec = get_function(name)
        assert math.isnan(float(spec.row(np.empty(0, np.float32))))
        b = spec.batched(np.zeros((3, 0), np.float32))
        assert b.shape == (3,) and np.isnan(b).all()
    spec = get_function("SUM")
    assert float(spec.row(np.empty(0, np.float32))) == 0.0
    b = spec.batched(np.zeros((2, 0), np.float32))
    assert b.shape == (2,) and (b == 0.0).all()
