"""Membership-sketch soundness: bloom/dictionary pruning for = / IN /
CONTAINS may produce false positives (cost: a verify verdict) but must
never drop a chunk containing a matching value.

Covers randomized integer values (incl. negatives), unicode text, empty
samples, dictionary overflow into the bloom, bloom saturation, legacy
(sketch-less) records, and the backfill job that lifts them.
"""

import numpy as np
import pytest

import repro.core as dl
from repro.core.chunk_encoder import ChunkStatsTable
from repro.core.chunks import (ChunkStats, SKETCH_DICT_MAX,
                               SKETCH_MAX_DISTINCT, _StatsAccumulator,
                               bloom_might_contain)
from repro.core.tql import execute_query

from _hypothesis_compat import given, settings, strategies as st


# ------------------------------------------------------------- sketch units
@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-(2 ** 40), 2 ** 40), min_size=0, max_size=120),
       st.integers(-(2 ** 40), 2 ** 40))
def test_int_sketch_never_false_negative(values, probe):
    acc = _StatsAccumulator(np.dtype("int64"))
    for v in values:
        acc.observe(np.asarray(v, np.int64))
    st_ = acc.snapshot(0)
    assert st_.sketched
    for v in values:
        assert st_.might_contain(int(v)), f"false negative for {v}"
    if probe not in values and st_.dct is not None:
        assert not st_.might_contain(probe)  # dictionary is exact


def test_str_sketch_unicode_and_empty_samples():
    acc = _StatsAccumulator(np.dtype("uint8"))
    texts = ["bänd β", "ボンド 3", "plain", ""]
    for s in texts:
        acc.observe(np.frombuffer(s.encode(), np.uint8))
    st_ = acc.snapshot(0)
    assert st_.dom == "str"
    # empty samples contribute no value, everything else round-trips
    assert st_.dct == sorted(s for s in texts if s)
    assert st_.min_elems == 0  # the planner's empty-sample escape hatch
    for s in texts:
        if s:
            assert st_.might_contain(s)
    assert not st_.might_contain("absent")


def test_dict_overflow_falls_back_to_bloom_then_disables():
    acc = _StatsAccumulator(np.dtype("int64"))
    n = SKETCH_DICT_MAX + 20
    for v in range(n):
        acc.observe(np.asarray(v, np.int64))
    st_ = acc.snapshot(0)
    assert st_.dct is None and st_.bloom is not None
    for v in range(n):
        assert st_.might_contain(v)          # bloom: no false negatives
    acc2 = _StatsAccumulator(np.dtype("int64"))
    for v in range(SKETCH_MAX_DISTINCT + 10):
        acc2.observe(np.asarray(v, np.int64))
    st2 = acc2.snapshot(0)
    assert st2.dct is None and st2.bloom is None and st2.dom is None
    assert st2.might_contain(0)              # saturated sketch = unknown


def test_bloom_wire_roundtrip():
    acc = _StatsAccumulator(np.dtype("int64"))
    for v in range(SKETCH_DICT_MAX + 10):
        acc.observe(np.asarray(v * 7 - 300, np.int64))
    st_ = ChunkStats.from_json(acc.snapshot(0).to_json())
    for v in range(SKETCH_DICT_MAX + 10):
        assert bloom_might_contain(st_.bloom, v * 7 - 300)
    assert not st_.might_contain(10 ** 12)


def test_float_and_oversized_samples_do_not_sketch():
    acc = _StatsAccumulator(np.dtype("float32"))
    acc.observe(np.ones(4, np.float32))
    assert acc.snapshot(0).dom is None
    acc = _StatsAccumulator(np.dtype("int64"))
    acc.observe(np.zeros(100000, np.int64))  # > SKETCH_MAX_ELEMS
    assert acc.snapshot(0).dom is None
    acc = _StatsAccumulator(np.dtype("uint8"))
    acc.observe(np.zeros((8, 8), np.uint8))  # 2-D uint8: not text
    assert acc.snapshot(0).dom is None


def test_str_dict_overflow_drops_sketch_entirely():
    """No consumer can use a bloom of whole strings (substring probes need
    the exact dictionary), so an overflowing str dictionary must not
    persist dead bloom bytes."""
    acc = _StatsAccumulator(np.dtype("uint8"))
    for i in range(SKETCH_DICT_MAX + 5):
        acc.observe(np.frombuffer(f"caption {i}".encode(), np.uint8))
    st_ = acc.snapshot(0)
    assert st_.dom is None and st_.dct is None and st_.bloom is None


def test_uint64_above_int63_float_literal_never_prunes():
    """Regression: an integral float literal outside int64 (e.g. 2**63 as
    the parser's float) CAN equal a uint64 element under the executor's
    float comparison — membership must bail, not claim absence."""
    ds = dl.Dataset()
    ds.create_tensor("u", dtype="uint64", min_chunk_size=128,
                     max_chunk_size=256)
    for i in range(100):
        ds.append({"u": np.uint64(2 ** 63 if i % 10 == 0 else i)})
    ds.commit("c")
    lit = repr(float(2 ** 63))  # 9.223372036854775808e18
    for q in (f"SELECT * FROM dataset WHERE u == {lit}",
              f"SELECT * FROM dataset WHERE u != {lit}",
              f"SELECT * FROM dataset WHERE u IN [{lit}]",
              f"SELECT * FROM dataset WHERE CONTAINS(u, {lit})"):
        on = execute_query(ds, q, use_stats=True)
        off = execute_query(ds, q, use_stats=False)
        assert on.indices.tolist() == off.indices.tolist(), q
    on = execute_query(ds, f"SELECT * FROM dataset WHERE u == {lit}",
                       use_stats=True)
    assert len(on) == 10  # the matching rows must survive planning


def test_legacy_record_never_answers_membership():
    legacy = ChunkStats.from_json(
        {"count": 3, "lo": 0.0, "hi": 9.0, "exact": True})
    assert not legacy.sketched
    assert legacy.might_contain(12345) and legacy.might_contain("x")


# -------------------------------------------------------------- end to end
def _gapped_dataset(storage=None, n=240):
    """Even labels only, two bands per chunk, plus per-band captions and a
    ragged tensor with genuinely empty samples."""
    ds = dl.Dataset(storage)
    ds.create_tensor("lab", htype="class_label", min_chunk_size=128,
                     max_chunk_size=256)
    ds.create_tensor("caption", htype="text", min_chunk_size=192,
                     max_chunk_size=384)
    ds.create_tensor("rag", dtype="int64", strict=False,
                     min_chunk_size=128, max_chunk_size=256)
    rng = np.random.default_rng(11)
    for i in range(n):
        band = i // 30
        ds.append({
            "lab": np.int64(band * 2),           # evens: odd probes gap
            "caption": np.frombuffer(f"bänd {band} ロウ".encode(),
                                     dtype=np.uint8).copy(),
            "rag": rng.integers(-3, 3, (i % 4,)).astype(np.int64),
        })
    ds.commit("fixture")
    return ds


MEMBERSHIP_QUERIES = [
    "SELECT * FROM dataset WHERE lab == 3",          # absent everywhere
    "SELECT * FROM dataset WHERE lab == 4",          # present in one band
    "SELECT * FROM dataset WHERE lab != 5",
    "SELECT * FROM dataset WHERE lab IN [1, 5, 9]",  # all absent
    "SELECT * FROM dataset WHERE lab IN [0, 2]",
    "SELECT * FROM dataset WHERE lab IN []",
    "SELECT * FROM dataset WHERE lab IN [2.5, 7]",   # non-integral literal
    "SELECT * FROM dataset WHERE lab == 2.0",        # integral float literal
    "SELECT * FROM dataset WHERE CONTAINS(lab, 6)",
    "SELECT * FROM dataset WHERE CONTAINS(lab, 7)",
    'SELECT * FROM dataset WHERE CONTAINS(caption, "bänd 3")',
    'SELECT * FROM dataset WHERE CONTAINS(caption, "ロウ")',   # every row
    'SELECT * FROM dataset WHERE CONTAINS(caption, "nope")',
    'SELECT * FROM dataset WHERE CONTAINS(caption, "")',       # unanswerable
    "SELECT * FROM dataset WHERE rag == 1",          # empty samples present
    "SELECT * FROM dataset WHERE rag != 100",
    "SELECT * FROM dataset WHERE rag IN [50, 60]",   # empty IN list is True
    "SELECT * FROM dataset WHERE CONTAINS(rag, 77)",
    "SELECT * FROM dataset WHERE lab == 4 AND CONTAINS(caption, \"2\")",
]


@pytest.fixture(scope="module")
def ds():
    return _gapped_dataset()


@pytest.mark.parametrize("q", MEMBERSHIP_QUERIES)
def test_membership_equivalence(ds, q):
    on = execute_query(ds, q, use_stats=True)
    off = execute_query(ds, q, use_stats=False)
    assert on.indices.tolist() == off.indices.tolist()


def test_absent_equality_prunes_everything(ds):
    v = execute_query(ds, "SELECT * FROM dataset WHERE lab == 3",
                      use_stats=True)
    assert len(v) == 0
    assert v.scan_plan["rows_pruned"] == 240
    assert v.scan_plan["rows_verify"] == 0


def test_absent_in_prunes_everything(ds):
    v = execute_query(ds, "SELECT * FROM dataset WHERE lab IN [1, 5, 9]",
                      use_stats=True)
    assert len(v) == 0 and v.scan_plan["rows_verify"] == 0


def test_contains_text_decides_groups(ds):
    v = execute_query(
        ds, 'SELECT * FROM dataset WHERE CONTAINS(caption, "bänd 3")',
        use_stats=True)
    assert v.indices.tolist() == list(range(90, 120))
    plan = v.scan_plan
    # chunks of other bands prune; full band-3 chunks are sure
    assert plan["rows_pruned"] > 0 and plan["rows_sure"] > 0
    assert plan["rows_verify"] < 240


def test_membership_prunes_with_zero_payload_fetches():
    """Acceptance: equality/IN on a class_label column over S3 issues zero
    payload requests for non-matching chunks (here: all of them) — the
    verdict comes from the manifest-resident sketches alone."""
    base = dl.MemoryProvider()
    _gapped_dataset(base)
    for q in ("SELECT * FROM dataset WHERE lab == 3",
              "SELECT * FROM dataset WHERE lab IN [1, 5]"):
        s3 = dl.SimulatedS3Provider(base, time_scale=0)
        remote = dl.Dataset(s3)  # cold open: manifest pointer + segment
        s3.reset_stats()
        v = execute_query(remote, q, use_stats=True)
        assert len(v) == 0
        assert s3.stats["requests"] == 0, \
            f"{q}: sketch pruning still fetched payloads"


def test_sketchless_sidecar_degrades_then_backfill_lifts():
    """Legacy datasets (pre-sketch sidecars) keep working with verify
    verdicts; backfill_stats recomputes the records, reports the lift,
    and restores prune verdicts — results identical throughout."""
    base = dl.MemoryProvider()
    ds = _gapped_dataset(base, n=120)
    # strip the sketch fields from every persisted sidecar, and the manifest
    # with its (sketch-bearing) column-statistics section: a pre-sketch
    # dataset has neither
    import json
    from repro.core.manifest import MANIFEST_KEY, SEGMENT_PREFIX
    base.delete(MANIFEST_KEY)
    for key in list(base.list_keys(SEGMENT_PREFIX)):
        base.delete(key)
    for key in list(base.list_keys()):
        if not key.endswith("chunk_stats.json"):
            continue
        doc = json.loads(base.get(key).decode())
        for rec in doc.get("chunks", {}).values():
            for f in ("sketched", "dom", "dct", "bloom"):
                rec.pop(f, None)
        base.put(key, json.dumps(doc).encode())
    legacy = dl.Dataset(base)
    q = "SELECT * FROM dataset WHERE lab == 3"
    v = execute_query(legacy, q, use_stats=True)
    assert len(v) == 0
    plan = v.scan_plan
    assert plan["sketch_coverage"] < 1.0 and plan["chunks_sketchless"] > 0
    # interval bounds still prune the out-of-range bands; the odd-value
    # gap inside covered bands needs the sketch, so some rows verify
    assert plan["rows_verify"] > 0
    report = legacy.maintenance().backfill_stats()
    assert report.details["sketches_lifted"] > 0
    v2 = execute_query(legacy, q, use_stats=True)
    assert len(v2) == 0
    assert v2.scan_plan["sketch_coverage"] == 1.0
    assert v2.scan_plan["rows_verify"] == 0
