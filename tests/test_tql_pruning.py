"""Chunk-statistics TQL pushdown: pruning equivalence + request accounting.

Every query must return identical rows with stats pruning on vs. off — over
clustered numerics, NaN columns, empty samples, ragged tensors, and queries
the planner cannot analyze.  Selective queries over SimulatedS3Provider must
fetch strictly fewer chunks/bytes than a full scan.
"""

import numpy as np
import pytest

import repro.core as dl
from repro.core.chunk_encoder import ChunkStatsTable
from repro.core.tql import execute_query, parse, plan_where
from repro.core.views import DatasetView


def _build(storage=None, n=200):
    """Clustered dataset: 8 bands of 25 rows; every tensor chunked small so
    per-band values land in distinct chunks (pruning has granularity)."""
    rng = np.random.default_rng(42)
    ds = dl.Dataset(storage)
    ds.create_tensor("x", dtype="float32", min_chunk_size=512,
                     max_chunk_size=1024)
    ds.create_tensor("lab", htype="class_label", min_chunk_size=128,
                     max_chunk_size=256)
    ds.create_tensor("nanny", dtype="float32", min_chunk_size=128,
                     max_chunk_size=256)
    ds.create_tensor("rag", dtype="float32", strict=False,
                     min_chunk_size=256, max_chunk_size=512)
    ds.create_tensor("caption", htype="text")
    for i in range(n):
        band = i // 25
        nanny = np.float32(np.nan) if i % 7 == 0 else np.float32(band)
        ds.append({
            "x": (rng.standard_normal(8).astype(np.float32)
                  + np.float32(band * 10)),
            "lab": np.int64(band),
            "nanny": np.asarray([nanny], np.float32),
            # ragged, with genuinely empty samples every 5th row
            "rag": rng.uniform(1, 2, (i % 5,)).astype(np.float32),
            "caption": np.frombuffer(f"band {band} row".encode(),
                                     dtype=np.uint8).copy(),
        })
    ds.commit("fixture")
    return ds


@pytest.fixture(scope="module")
def ds():
    return _build()


EQUIVALENCE_QUERIES = [
    "SELECT * FROM dataset WHERE lab == 3",
    "SELECT * FROM dataset WHERE lab != 3",
    "SELECT * FROM dataset WHERE NOT lab == 2",
    "SELECT * FROM dataset WHERE lab >= 6 OR lab < 1",
    "SELECT * FROM dataset WHERE lab * 2 + 1 > 9",
    "SELECT * FROM dataset WHERE MEAN(x) > 45",
    "SELECT * FROM dataset WHERE MEAN(x) > 45 AND lab != 7",
    "SELECT * FROM dataset WHERE MAX(x) < 20 OR lab == 7",
    "SELECT * FROM dataset WHERE ABS(MEAN(x) - 50) < 10",
    "SELECT * FROM dataset WHERE MIN(x) > 1000",          # prune everything
    "SELECT * FROM dataset WHERE lab >= 0",               # keep everything
    # NaN column: == / != / reductions must respect IEEE semantics
    "SELECT * FROM dataset WHERE nanny == 4",
    "SELECT * FROM dataset WHERE nanny != 4",
    "SELECT * FROM dataset WHERE MEAN(nanny) > 5.5",
    "SELECT * FROM dataset WHERE nanny != 1000000",
    # empty samples / ragged tensors
    "SELECT * FROM dataset WHERE rag > 0",
    "SELECT * FROM dataset WHERE MEAN(rag) > 1.5",
    "SELECT * FROM dataset WHERE SUM(rag) > 4",
    "SELECT * FROM dataset WHERE rag > 0 AND lab == 2",
    # planner-opaque expressions fall back to verify
    'SELECT * FROM dataset WHERE CONTAINS(caption, "band 3")',
    "SELECT * FROM dataset WHERE SHAPE(rag)[0] == 3",
    "SELECT * FROM dataset WHERE lab IN [1, 5]",
    "SELECT * FROM dataset WHERE RANDOM() < 0.5",
    "SELECT * FROM dataset WHERE RANDOM() < 0.5 AND lab == 3",
    # pipelines after WHERE must see identical row sets
    "SELECT * FROM dataset WHERE lab == 3 ORDER BY MEAN(x) DESC LIMIT 7",
    "SELECT MEAN(x) AS m, lab FROM dataset WHERE lab == 5 LIMIT 9",
]


@pytest.mark.parametrize("q", EQUIVALENCE_QUERIES)
def test_pruning_equivalence(ds, q):
    on = execute_query(ds, q, use_stats=True)
    off = execute_query(ds, q, use_stats=False)
    assert on.indices.tolist() == off.indices.tolist()
    for k in on.derived:
        a = [np.asarray(v).tolist() for v in on.derived[k]]
        b = [np.asarray(v).tolist() for v in off.derived[k]]
        assert a == b


def test_selective_query_actually_prunes(ds):
    v = execute_query(ds, "SELECT * FROM dataset WHERE lab == 3",
                      use_stats=True)
    plan = v.scan_plan
    assert plan is not None and plan["rows_pruned"] > 0
    assert plan["chunks_pruned"] > 0
    assert plan["rows_pruned"] + plan["rows_sure"] + plan["rows_verify"] \
        == plan["rows"] == 200


def test_always_true_predicate_is_sure(ds):
    v = execute_query(ds, "SELECT * FROM dataset WHERE lab >= 0",
                      use_stats=True)
    assert len(v) == 200
    assert v.scan_plan["rows_sure"] == 200
    assert v.scan_plan["rows_verify"] == 0


def test_always_false_predicate_prunes_all(ds):
    v = execute_query(ds, "SELECT * FROM dataset WHERE MIN(x) > 1000",
                      use_stats=True)
    assert len(v) == 0
    assert v.scan_plan["rows_pruned"] == 200


def test_random_disables_planning(ds):
    v = execute_query(ds, "SELECT * FROM dataset WHERE RANDOM() < 0.5",
                      use_stats=True)
    assert v.scan_plan is None


def test_unanalyzable_predicate_verifies_everything(ds):
    v = execute_query(
        ds, 'SELECT * FROM dataset WHERE CONTAINS(caption, "band 3")',
        use_stats=True)
    assert v.scan_plan["groups_decided"] == 0
    assert v.scan_plan["rows_verify"] == 200


def test_plan_where_direct(ds):
    view = DatasetView.full(ds)
    q = parse("SELECT * FROM dataset WHERE lab == 0")
    plan = plan_where(view, q.where)
    assert plan is not None
    assert sorted(plan.sure.tolist() + plan.verify.tolist()
                  + plan.pruned.tolist()) == list(range(200))
    # band 0 rows (0..24) must never be pruned
    assert not set(plan.pruned.tolist()) & set(range(25))


def test_missing_stats_degrade_to_full_scan():
    """Datasets without the sidecar (pre-stats format) stay correct."""
    ds = _build(n=100)  # private copy: blanking stats must not leak into the
    view = DatasetView.full(ds)  # module-scoped fixture other tests share
    for name in ("x", "lab"):
        view._base_tensor(name).stats = ChunkStatsTable()
    on = execute_query(view, "SELECT * FROM view WHERE lab == 3",
                       use_stats=True)
    off = execute_query(ds, "SELECT * FROM dataset WHERE lab == 3",
                        use_stats=False)
    assert on.indices.tolist() == off.indices.tolist()


def test_stats_survive_reload_and_commit():
    ds = _build(n=100)
    # fresh Dataset over the same storage: sidecar must load back
    ds2 = dl.Dataset(ds.storage)
    v = execute_query(ds2, "SELECT * FROM dataset WHERE lab == 1",
                      use_stats=True)
    assert v.scan_plan["rows_pruned"] > 0
    assert v.indices.tolist() == list(range(25, 50))
    # commit copies the sidecar with the encoder snapshot
    ds2.commit("noop")
    v2 = execute_query(ds2, "SELECT * FROM dataset WHERE lab == 1",
                       use_stats=True)
    assert v2.scan_plan["rows_pruned"] > 0
    assert v2.indices.tolist() == list(range(25, 50))


def test_update_recomputes_stats():
    """COW rewrite of a sealed chunk must refresh its stats: a value moved
    outside the old bounds is still found by a stats-pruned query."""
    ds = _build(n=100)
    ds.lab[0] = np.int64(3)   # band 0 row now matches lab == 3
    on = execute_query(ds, "SELECT * FROM dataset WHERE lab == 3",
                       use_stats=True)
    off = execute_query(ds, "SELECT * FROM dataset WHERE lab == 3",
                        use_stats=False)
    assert 0 in on.indices.tolist()
    assert on.indices.tolist() == off.indices.tolist()


def test_versioned_query_uses_that_versions_stats():
    ds = _build(n=100)
    c0 = ds.commit("v0")
    ds.lab[10] = np.int64(7)
    ds.commit("v1")
    q = f'SELECT * FROM dataset VERSION "{c0}" WHERE lab == 0'
    on = execute_query(ds, q, use_stats=True)
    off = execute_query(ds, q, use_stats=False)
    assert on.indices.tolist() == off.indices.tolist() == list(range(25))


def test_selective_query_fetches_fewer_chunks_from_s3():
    # independent provider+dataset per measurement: the scan pipeline
    # parks prefetched chunks in the provider's shared engine, so a second
    # query over the same provider would measure a warm resident store
    q = "SELECT * FROM dataset WHERE MEAN(x) > 45 AND lab != 7"

    def measure(use_stats):
        s3 = dl.SimulatedS3Provider(time_scale=0)
        ds = _build(storage=s3)  # state caches warm (built in-process)
        s3.reset_stats()
        view = execute_query(ds, q, use_stats=use_stats)
        return view, dict(s3.stats)

    off, full = measure(False)
    on, pruned = measure(True)
    assert on.indices.tolist() == off.indices.tolist()
    assert len(on) > 0
    # strictly fewer requests and payload bytes than the full scan
    assert pruned["requests"] < full["requests"]
    assert pruned["bytes_down"] < full["bytes_down"]


def test_float32_rounding_never_flips_verdicts():
    """Planner intervals (float64) must absorb float32 evaluation rounding:
    bound-hugging predicates may not prune rows the engine would keep (or
    keep rows it would drop)."""
    ds = dl.Dataset()
    ds.create_tensor("x", dtype="float32", min_chunk_size=256,
                     max_chunk_size=512)
    for _ in range(40):
        ds.append({"x": np.full(4, 0.4, np.float32)})
    ds.commit("c")
    for q in ("SELECT * FROM dataset WHERE x + 16777216 > 16777216",
              "SELECT * FROM dataset WHERE x + 16777216 <= 16777216",
              "SELECT * FROM dataset WHERE CAST_FLOAT(x) == 0.4",
              "SELECT * FROM dataset WHERE MEAN(x) == 0.4"):
        on = execute_query(ds, q, use_stats=True)
        off = execute_query(ds, q, use_stats=False)
        assert on.indices.tolist() == off.indices.tolist(), q


def test_int64_overflow_never_pruned():
    """Arithmetic whose interval exceeds the int64-safe range must verify,
    not prune: the engine's int64 math wraps."""
    ds = dl.Dataset()
    ds.create_tensor("b", dtype="int64", min_chunk_size=256,
                     max_chunk_size=512)
    for _ in range(20):
        ds.append({"b": np.full(2, 2 ** 62, np.int64)})
    ds.commit("c")
    q = "SELECT * FROM dataset WHERE b * 4 > 0"  # wraps to 0 in int64
    on = execute_query(ds, q, use_stats=True)
    off = execute_query(ds, q, use_stats=False)
    assert on.indices.tolist() == off.indices.tolist()


def test_query_view_hands_prune_accounting_to_loader():
    ds = _build(n=100)
    v = execute_query(ds, "SELECT * FROM dataset WHERE lab == 2",
                      use_stats=True)
    loader = v.dataloader(batch_size=8, tensors=["x", "lab"], num_workers=2)
    rows = sum(len(b["lab"]) for b in loader)
    assert rows == len(v) == 25
    assert loader.stats.chunks_pruned == v.scan_plan["chunks_pruned"] > 0
    assert loader.costs.counters["chunks_pruned"] > 0
