"""ORDER BY + LIMIT top-k pushdown: byte-identical equivalence with the
legacy whole-column sort, int64 key precision, chunk-group skipping.

The pushdown path (`Executor._order_limit_topk`) streams chunk groups
best-bound-first and terminates on a running k-th-element cutoff; every
test here cross-checks it against ``stream=False`` (the legacy path), which
must agree byte-for-byte across ASC/DESC, ties, NaN keys, OFFSET, LIMIT
beyond the result size, and RANDOM()-disabled plans.
"""

import numpy as np
import pytest

import repro.core as dl
from repro.core.tql import execute_query

from _hypothesis_compat import given, settings, strategies as st


def _keyed_dataset(values, dtype="int64", chunk=96):
    """One-key-per-row dataset, chunked small so top-k has granularity."""
    ds = dl.Dataset()
    ds.create_tensor("x", dtype=dtype, min_chunk_size=chunk // 2,
                     max_chunk_size=chunk)
    ds.create_tensor("tag", dtype="int64", min_chunk_size=chunk // 2,
                     max_chunk_size=chunk)
    for i, v in enumerate(values):
        ds.append({"x": np.asarray(v, dtype=dtype), "tag": np.int64(i)})
    ds.commit("fixture")
    return ds


def _both(ds, q):
    on = execute_query(ds, q)                    # stream=None: auto/topk
    off = execute_query(ds, q, stream=False)     # legacy whole-column sort
    assert on.indices.tolist() == off.indices.tolist(), q
    for k in on.derived:
        a = [np.asarray(v).tolist() for v in on.derived[k]]
        b = [np.asarray(v).tolist() for v in off.derived[k]]
        assert a == b, q
    return on


# ------------------------------------------------------------ key precision
def test_order_by_keeps_int64_precision():
    """Satellite regression: float64-cast keys collapse int64 values above
    2**53 into ties and mis-order them; native-dtype keys must not."""
    base = 2 ** 53
    vals = [base + 3, base, base + 1, base + 2, base + 5, base + 4]
    ds = _keyed_dataset(vals * 4)  # shuffled-ish repeats across chunks
    view = execute_query(ds, "SELECT * FROM dataset ORDER BY x ASC")
    got = [int(np.asarray(v)) for v in
           (ds.x.read(int(i)) for i in view.indices)]
    assert got == sorted(int(v) for v in vals * 4)
    # and through the top-k path (LIMIT engages the pushdown)
    top = _both(ds, "SELECT * FROM dataset ORDER BY x ASC LIMIT 5")
    got_top = [int(ds.x.read(int(i))) for i in top.indices]
    assert got_top == sorted(int(v) for v in vals * 4)[:5]


def test_order_by_desc_tie_order_matches_legacy():
    """Legacy DESC is the full reversal of a stable ascending sort: ties
    appear in descending position order.  The pushdown must reproduce it."""
    ds = _keyed_dataset([5, 1, 5, 3, 5, 1, 3, 5] * 6)
    v = _both(ds, "SELECT * FROM dataset ORDER BY x DESC LIMIT 10")
    assert v.topk_plan is not None  # the pushdown actually ran


# ------------------------------------------------------------- equivalence
@settings(max_examples=12, deadline=None)
@given(st.lists(st.integers(-40, 40), min_size=2, max_size=70),
       st.booleans(),
       st.integers(1, 12),
       st.integers(0, 6))
def test_topk_equivalence_int_keys(vals, desc, limit, offset):
    ds = _keyed_dataset(vals)
    q = (f"SELECT * FROM dataset ORDER BY x {'DESC' if desc else 'ASC'} "
         f"LIMIT {limit} OFFSET {offset}")
    _both(ds, q)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(-5, 5), min_size=2, max_size=60),
       st.booleans(),
       st.integers(1, 9))
def test_topk_equivalence_float_keys_with_nans(vals, desc, limit):
    vals = [float("nan") if (i % 4 == 1) else v for i, v in enumerate(vals)]
    ds = _keyed_dataset(vals, dtype="float32")
    q = (f"SELECT * FROM dataset ORDER BY x {'DESC' if desc else 'ASC'} "
         f"LIMIT {limit}")
    _both(ds, q)


def test_topk_limit_beyond_result_size():
    ds = _keyed_dataset(list(range(30)))
    v = _both(ds, "SELECT * FROM dataset ORDER BY x DESC LIMIT 500")
    assert len(v) == 30
    v = _both(ds, "SELECT * FROM dataset ORDER BY x LIMIT 500 OFFSET 25")
    assert len(v) == 5


def test_topk_after_where_and_with_projection():
    ds = _keyed_dataset(list(range(80)))
    _both(ds, "SELECT * FROM dataset WHERE x >= 10 ORDER BY x DESC LIMIT 7")
    _both(ds, "SELECT x, tag AS t FROM dataset ORDER BY x DESC "
              "LIMIT 5 OFFSET 2")
    _both(ds, "SELECT MEAN(x) AS m FROM dataset ORDER BY x LIMIT 6")


def test_topk_expression_keys():
    rng = np.random.default_rng(3)
    ds = dl.Dataset()
    ds.create_tensor("v", dtype="float32", min_chunk_size=1 << 10,
                     max_chunk_size=1 << 11)
    for i in range(200):
        ds.append({"v": (rng.standard_normal(16).astype(np.float32)
                         + np.float32(5 * (i // 25)))})
    ds.commit("c")
    _both(ds, "SELECT * FROM dataset ORDER BY MEAN(v) DESC LIMIT 11")
    _both(ds, "SELECT * FROM dataset ORDER BY MEAN(v) * -2 + 1 LIMIT 9")
    _both(ds, "SELECT * FROM dataset ORDER BY ABS(MEAN(v) - 10) LIMIT 8")


def test_random_disables_topk():
    """RANDOM() anywhere in the query draws from an order-dependent stream:
    the pushdown must stand down and both paths must still agree."""
    ds = _keyed_dataset(list(range(60)))
    for q in ("SELECT * FROM dataset WHERE RANDOM() < 2 "
              "ORDER BY x DESC LIMIT 5",
              "SELECT RANDOM() AS r, x FROM dataset ORDER BY x LIMIT 5"):
        v = _both(ds, q)
        assert v.topk_plan is None, q


def test_arrange_and_sample_by_disable_topk():
    ds = _keyed_dataset([1, 3, 2, 4] * 20)
    v = _both(ds, "SELECT * FROM dataset ORDER BY x ARRANGE BY tag % 3 "
                  "LIMIT 6")
    assert v.topk_plan is None
    v = execute_query(ds, "SELECT * FROM dataset ORDER BY x "
                          "SAMPLE BY x LIMIT 6")
    assert v.topk_plan is None and len(v) == 6


# ---------------------------------------------------------- actual skipping
def test_topk_skips_chunk_groups_and_requests():
    """Selective top-k over simulated S3 fetches strictly fewer chunks than
    the legacy whole-column sort, with identical results."""
    q = "SELECT * FROM dataset ORDER BY x DESC LIMIT 8"

    def measure(stream):
        s3 = dl.SimulatedS3Provider(time_scale=0)
        ds = dl.Dataset(s3)
        ds.create_tensor("x", dtype="int64", min_chunk_size=128,
                         max_chunk_size=256)
        for i in range(400):
            ds.append({"x": np.int64(i)})
        ds.commit("c")
        s3.reset_stats()
        view = execute_query(ds, q, stream=stream)
        return view, dict(s3.stats)

    legacy, full = measure(False)
    topk, pushed = measure(None)
    assert topk.indices.tolist() == legacy.indices.tolist()
    assert topk.topk_plan is not None
    assert topk.topk_plan["groups_skipped"] > 0
    assert topk.topk_plan["terminated_early"] == 1
    assert pushed["requests"] * 2 <= full["requests"], \
        (f"top-k did not halve requests: {full['requests']} -> "
         f"{pushed['requests']}")
    assert pushed["bytes_down"] < full["bytes_down"]


def test_topk_report_reaches_dataloader_stats():
    ds = _keyed_dataset(list(range(120)))
    v = execute_query(ds, "SELECT * FROM dataset ORDER BY x DESC LIMIT 6")
    assert v.topk_plan is not None and v.topk_plan["groups_skipped"] > 0
    loader = v.dataloader(batch_size=4, tensors=["x"], num_workers=2)
    rows = sum(len(b["x"]) for b in loader)
    assert rows == 6
    assert loader.stats.topk_groups_skipped == v.topk_plan["groups_skipped"]
    assert loader.costs.counters["topk_groups_skipped"] > 0


def test_topk_with_unknown_bounds_still_exact():
    """Chunks without usable stats get unbounded (stream-first) bounds:
    no skipping, same answer."""
    ds = _keyed_dataset(list(range(50)))
    view = execute_query(ds, "SELECT * FROM dataset")  # plain copy
    for name in ("x", "tag"):
        from repro.core.chunk_encoder import ChunkStatsTable
        view._base_tensor(name).stats = ChunkStatsTable()
    on = execute_query(view, "SELECT * FROM view ORDER BY x DESC LIMIT 5")
    off = execute_query(view, "SELECT * FROM view ORDER BY x DESC LIMIT 5",
                        stream=False)
    assert on.indices.tolist() == off.indices.tolist()
