"""Version control (C2): commit/checkout/diff/merge + time-travel properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as dl


def _mk(n=10, chunk=512):
    ds = dl.dataset()
    ds.create_tensor("x", dtype="int64", min_chunk_size=chunk // 2,
                     max_chunk_size=chunk)
    for i in range(n):
        ds.x.append(np.full((8,), i, np.int64))
    return ds


def test_commit_seals_and_time_travel():
    ds = _mk()
    c0 = ds.commit("v0")
    ds.x[0] = np.full((8,), 100, np.int64)
    ds.x.append(np.full((8,), 10, np.int64))
    c1 = ds.commit("v1")
    old = ds.tensor_at("x", c0)
    assert len(old) == 10
    np.testing.assert_array_equal(old.read(0), np.full((8,), 0, np.int64))
    np.testing.assert_array_equal(ds.x[0], np.full((8,), 100, np.int64))
    assert len(ds.x) == 11
    log = ds.log()
    assert [n.message for n in log] == ["v1", "v0"]


def test_sealed_head_is_readonly():
    ds = _mk()
    c0 = ds.commit("v0")
    ds.checkout(c0)
    with pytest.raises(PermissionError):
        ds.x.append(np.zeros((8,), np.int64))
    ds.checkout("main")
    ds.x.append(np.zeros((8,), np.int64))  # head is writable again


def test_branching_isolation():
    ds = _mk()
    ds.commit("base")
    ds.checkout("exp", create=True)
    ds.x[1] = np.full((8,), -1, np.int64)
    ds.commit("exp change")
    ds.checkout("main")
    np.testing.assert_array_equal(ds.x[1], np.full((8,), 1, np.int64))
    ds.checkout("exp")
    np.testing.assert_array_equal(ds.x[1], np.full((8,), -1, np.int64))


def test_diff_reports_both_sides():
    ds = _mk()
    ds.commit("base")
    ds.checkout("b", create=True)
    ds.x[2] = np.full((8,), 22, np.int64)
    ds.x.append(np.full((8,), 11, np.int64))
    ds.flush()
    d = ds.diff("main", "b")
    assert d["b"]["x"]["updated"] == [2]
    assert d["b"]["x"]["added_count"] == 1
    assert d["a"] == {}


def test_merge_appends_and_updates():
    ds = _mk()
    ds.commit("base")
    ds.checkout("feature", create=True)
    ds.x[4] = np.full((8,), 44, np.int64)
    ds.x.append(np.full((8,), 77, np.int64))
    ds.commit("feature work")
    ds.checkout("main")
    ds.x[0] = np.full((8,), 5, np.int64)   # non-conflicting local change
    ds.merge("feature")
    np.testing.assert_array_equal(ds.x[4], np.full((8,), 44, np.int64))
    np.testing.assert_array_equal(ds.x[0], np.full((8,), 5, np.int64))
    assert len(ds.x) == 11
    np.testing.assert_array_equal(ds.x[10], np.full((8,), 77, np.int64))


def test_merge_conflict_policies():
    for policy, want in (("theirs", 99), ("ours", 11), ("raise", None)):
        ds = _mk()
        ds.commit("base")
        ds.checkout("b", create=True)
        ds.x[3] = np.full((8,), 99, np.int64)
        ds.commit("theirs")
        ds.checkout("main")
        ds.x[3] = np.full((8,), 11, np.int64)
        ds.flush()
        if policy == "raise":
            with pytest.raises(dl.MergeConflict):
                ds.merge("b", policy="raise")
        else:
            ds.merge("b", policy=policy)
            np.testing.assert_array_equal(
                ds.x[3], np.full((8,), want, np.int64))


def test_merge_new_tensor_from_branch():
    ds = _mk()
    ds.commit("base")
    ds.checkout("b", create=True)
    ds.create_tensor("y", dtype="int32")
    ds.y.extend([np.int32(i) for i in range(3)])
    ds.commit("add y")
    ds.checkout("main")
    ds.merge("b")
    assert "y" in ds.tensor_names
    assert int(ds.y[2]) == 2


def test_schema_evolution_is_versioned():
    ds = _mk()
    c0 = ds.commit("before schema change")
    ds.create_tensor("z", dtype="float32")
    ds.commit("with z")
    assert "z" in ds.tensor_names
    assert "z" not in ds.vc.schema_tensors(c0)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(-100, 100)),
                min_size=1, max_size=8))
def test_time_travel_property(edit_script):
    """Any sequence of commit+edit rounds: every commit replays exactly."""
    ds = _mk()
    expected = {i: i for i in range(10)}   # idx -> scalar value
    snapshots = []
    for idx, val in edit_script:
        cid = ds.commit(f"edit {idx}")
        snapshots.append((cid, dict(expected)))
        ds.x[idx] = np.full((8,), val, np.int64)
        expected[idx] = val
    final = ds.commit("final")
    snapshots.append((final, dict(expected)))
    for cid, snap in snapshots:
        t = ds.tensor_at("x", cid)
        for i, v in snap.items():
            np.testing.assert_array_equal(t.read(i), np.full((8,), v, np.int64))


def test_versioned_query_and_view_save():
    ds = _mk()
    c0 = ds.commit("v0")
    ds.x[0] = np.full((8,), 1000, np.int64)
    ds.commit("v1")
    v = ds.query(f'SELECT * FROM dataset VERSION "{c0}" WHERE MEAN(x) < 5')
    assert len(v) == 5
    vid = v.save()
    v2 = dl.DatasetView.load(ds, vid)
    assert np.array_equal(v2.indices, v.indices)
    np.testing.assert_array_equal(v2.tensor("x").read(0), np.zeros((8,), np.int64))
